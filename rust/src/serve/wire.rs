//! Versioned, length-prefixed binary wire protocol for the TCP serving
//! subsystem (`docs/wire-protocol.md` is the normative spec).
//!
//! Frame layout (little-endian, 20-byte fixed header):
//!
//! ```text
//! magic "EMWP" | u16 version | u8 opcode | u8 status | u64 request_id |
//! u32 payload_len | payload bytes
//! ```
//!
//! Version 2 adds a model-name field to `Infer`/`InferBatch` (routing
//! across the multi-model registry), a two-name `SwapModel` payload
//! (slot + source) and the `ListModels` opcode. Version 3 adds
//! per-request quality-of-service fields to `Infer`/`InferBatch`
//! (`u64 deadline_us | u8 priority`, see [`Qos`]), the [`Opcode::Health`]
//! opcode (per-pool queue depth, shed/expiry counters, degraded-mode
//! state) and the [`Status::Expired`]/[`Status::Timeout`] statuses.
//! Version 4 adds the observability opcodes — [`Opcode::DumpTrace`]
//! (Chrome trace-event JSON payload of the server's request-lifecycle
//! ring buffer) and [`Opcode::StatsV2`] (machine-readable Prometheus
//! text exposition, the same families `GET /metrics` serves) — and an
//! extension block on the `Health` response carrying the
//! busy-rejection and bad-request-by-cause counters. Pre-v4 `Health`
//! responses omit the extension, so v3 clients decode exactly the
//! bytes they always did. Version 4 also carries the numeric-precision
//! extensions: `ListModels` responses append one [`Precision`] byte per
//! slot after the entry table, and `SwapModel` requests may append one
//! optional [`Precision`] byte pinning the slot's serving precision —
//! both strict suffix extensions, so every pre-v4 byte stays exactly
//! where v1–v3 clients expect it.
//!
//! Version-1 through version-3 frames are still accepted: their
//! payloads carry no QoS fields and default to "no deadline, normal
//! priority" (v1 additionally carries no model name and resolves to
//! the server's default model), and the server answers each request at
//! the version it arrived with (see `decode_*`'s `version` parameter).
//!
//! Requests always carry status [`Status::Ok`]; responses echo the
//! request's opcode, id and version. A non-`Ok` status turns the
//! payload into a UTF-8 error message. Coordinator-level failure modes
//! map onto the status byte (`SubmitError::Backpressure` →
//! [`Status::Backpressure`], `SubmitError::Closed` →
//! [`Status::Closed`]) so clients can tell "retry later" apart from
//! "server going away" without parsing text.

use std::io::{ErrorKind, Read, Write};
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

/// Frame magic: "EMWP" (EdgeMlp Wire Protocol).
pub const MAGIC: [u8; 4] = *b"EMWP";
/// Current protocol version; bumped on any incompatible frame-layout
/// change.
pub const VERSION: u16 = 4;
/// Oldest version still accepted (v1 payloads carry no model names).
pub const MIN_VERSION: u16 = 1;
/// Fixed header size in bytes.
pub const HEADER_LEN: usize = 20;
/// Default cap on payload size — guards the server (and client) against
/// hostile or corrupt length prefixes.
pub const DEFAULT_MAX_PAYLOAD: u32 = 16 * 1024 * 1024;
/// `Infer`/`InferBatch` backend field value asking the server to pick
/// the least-loaded of the model's pools.
pub const BACKEND_ANY: u32 = u32::MAX;
/// Cap on the v2 model-name field. Anything longer is a malformed
/// payload — enforced before the name bytes are read.
pub const MAX_MODEL_NAME_LEN: usize = 255;
/// Cap on the v3 `deadline_us` field (1 hour). A deadline beyond this
/// is a malformed payload, not a very patient client — it guards
/// against nonsense values like `u64::MAX` overflowing deadline
/// arithmetic server-side.
pub const MAX_DEADLINE_US: u64 = 3_600_000_000;

/// Request kinds a client can send; responses echo the opcode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Opcode {
    /// Liveness probe; the response echoes the request payload.
    Ping = 0,
    /// One flattened sample → one output vector.
    Infer = 1,
    /// A batch of same-dimension samples in one frame.
    InferBatch = 2,
    /// Metrics snapshot (text payload with latency percentiles).
    Stats = 3,
    /// Activate a registered model version into a serving slot.
    SwapModel = 4,
    /// Enumerate the served models (v2 only).
    ListModels = 5,
    /// Resilience snapshot: per-pool queue depth, shed/expiry counters
    /// and degraded-mode state (v3+).
    Health = 6,
    /// Dump the server's request-lifecycle trace ring buffer. The
    /// request payload is empty; the response payload is Chrome
    /// trace-event JSON, loadable in Perfetto / `chrome://tracing`
    /// (v4 only).
    DumpTrace = 7,
    /// Machine-readable metrics snapshot: the response payload is the
    /// Prometheus text exposition (format 0.0.4) — byte-identical
    /// families to what the `--metrics-addr` HTTP sidecar serves, so
    /// wire-only clients aren't second-class (v4 only).
    StatsV2 = 8,
}

impl Opcode {
    pub fn from_u8(v: u8) -> Option<Opcode> {
        match v {
            0 => Some(Opcode::Ping),
            1 => Some(Opcode::Infer),
            2 => Some(Opcode::InferBatch),
            3 => Some(Opcode::Stats),
            4 => Some(Opcode::SwapModel),
            5 => Some(Opcode::ListModels),
            6 => Some(Opcode::Health),
            7 => Some(Opcode::DumpTrace),
            8 => Some(Opcode::StatsV2),
            _ => None,
        }
    }
}

/// Response status byte. Anything but `Ok` makes the payload a UTF-8
/// error message.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Status {
    Ok = 0,
    /// Load shed: the target backend queue was full (retry later).
    Backpressure = 1,
    /// The coordinator is shutting down.
    Closed = 2,
    /// No backend at the requested index.
    UnknownBackend = 3,
    /// Request frame decoded but its payload was malformed.
    BadRequest = 4,
    /// The backend accepted the request and then failed.
    BackendError = 5,
    /// The request named a model (or serving slot) the server does not
    /// hold.
    UnknownModel = 6,
    /// Connection rejected: the server is at its connection limit.
    Busy = 7,
    /// Unexpected server-side failure (response channel lost, timeout).
    Internal = 8,
    /// The request's deadline cannot be (or was not) met: rejected at
    /// admission because the estimated queue wait already exceeds the
    /// deadline, or expired in the queue before a worker reached it.
    /// No inference was computed (v3).
    Expired = 9,
    /// The connection sat idle (or mid-frame) past the server's read
    /// deadline and is being closed to free its slot (v3).
    Timeout = 10,
}

impl Status {
    pub fn from_u8(v: u8) -> Option<Status> {
        match v {
            0 => Some(Status::Ok),
            1 => Some(Status::Backpressure),
            2 => Some(Status::Closed),
            3 => Some(Status::UnknownBackend),
            4 => Some(Status::BadRequest),
            5 => Some(Status::BackendError),
            6 => Some(Status::UnknownModel),
            7 => Some(Status::Busy),
            8 => Some(Status::Internal),
            9 => Some(Status::Expired),
            10 => Some(Status::Timeout),
            _ => None,
        }
    }
}

impl std::fmt::Display for Status {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{self:?}")
    }
}

/// v3 request priority. Lower [`Priority::rank`] is served first; ties
/// (and every pre-v3 request) keep FIFO order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
#[repr(u8)]
pub enum Priority {
    /// The default for every request, including all v1/v2 traffic.
    #[default]
    Normal = 0,
    /// Jumps the queue ahead of `Normal`/`Low` work.
    High = 1,
    /// Yields to everything else (offline/batch traffic).
    Low = 2,
}

impl Priority {
    pub fn from_u8(v: u8) -> Option<Priority> {
        match v {
            0 => Some(Priority::Normal),
            1 => Some(Priority::High),
            2 => Some(Priority::Low),
            _ => None,
        }
    }

    /// Scheduling rank: smaller runs first (High < Normal < Low). This
    /// is deliberately distinct from the wire byte, which keeps 0 as
    /// the compatible "normal" default.
    pub fn rank(self) -> u8 {
        match self {
            Priority::High => 0,
            Priority::Normal => 1,
            Priority::Low => 2,
        }
    }
}

/// Per-request quality of service carried by v3 `Infer`/`InferBatch`
/// payloads. The deadline is a *relative* completion budget in
/// microseconds from the moment the server decodes the request — never
/// an absolute timestamp, so client and server clocks need not agree.
/// `deadline_us == 0` means "no deadline" (the v1/v2 behavior).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Qos {
    /// Completion budget in µs from server receipt; 0 = none. Capped at
    /// [`MAX_DEADLINE_US`] by the codec.
    pub deadline_us: u64,
    pub priority: Priority,
}

impl Qos {
    /// No deadline, normal priority — what every v1/v2 request gets.
    pub const NONE: Qos = Qos { deadline_us: 0, priority: Priority::Normal };

    pub fn with_deadline_us(deadline_us: u64) -> Qos {
        Qos { deadline_us, priority: Priority::Normal }
    }

    pub fn has_deadline(&self) -> bool {
        self.deadline_us > 0
    }

    fn validate(&self) -> Result<(), String> {
        if self.deadline_us > MAX_DEADLINE_US {
            return Err(format!(
                "deadline {}µs exceeds cap {MAX_DEADLINE_US}µs",
                self.deadline_us
            ));
        }
        Ok(())
    }
}

/// Numeric precision a serving slot runs at — the v4 wire byte behind
/// the `ListModels` precision column and the optional `SwapModel`
/// precision preference (docs/quantization-modes.md).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
#[repr(u8)]
pub enum Precision {
    /// Full-precision f32 — the CPU baseline datapath.
    #[default]
    F32 = 0,
    /// SPx shift-add codebook quantization — the FPGA datapath.
    Spx = 1,
    /// VSQ int8: per-row-group scaled integer weights.
    Int8 = 2,
    /// VSQ int4: per-row-group scaled, packed low-bit integer weights.
    Int4 = 3,
}

impl Precision {
    pub fn from_u8(v: u8) -> Option<Precision> {
        match v {
            0 => Some(Precision::F32),
            1 => Some(Precision::Spx),
            2 => Some(Precision::Int8),
            3 => Some(Precision::Int4),
            _ => None,
        }
    }

    pub fn as_u8(self) -> u8 {
        self as u8
    }

    /// Stable lowercase label used by the CLI, pool metrics and docs.
    pub fn label(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Spx => "spx",
            Precision::Int8 => "int8",
            Precision::Int4 => "int4",
        }
    }

    /// Parse an operator spelling of a precision mode (CLI flags).
    pub fn parse(s: &str) -> Option<Precision> {
        match s.trim() {
            "f32" | "fp32" | "float" => Some(Precision::F32),
            "spx" => Some(Precision::Spx),
            "int8" | "i8" => Some(Precision::Int8),
            "int4" | "i4" => Some(Precision::Int4),
            _ => None,
        }
    }
}

impl std::fmt::Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// One protocol frame, request or response. `version` is the protocol
/// version the frame was (or will be) framed with — responses echo the
/// request's version so v1 clients never see v2 frames.
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    pub version: u16,
    pub opcode: Opcode,
    pub status: Status,
    pub request_id: u64,
    pub payload: Vec<u8>,
}

impl Frame {
    /// A success frame (request, or `Ok` response) at the current
    /// version.
    pub fn ok(opcode: Opcode, request_id: u64, payload: Vec<u8>) -> Frame {
        Frame { version: VERSION, opcode, status: Status::Ok, request_id, payload }
    }

    /// An error response: status + UTF-8 message payload.
    pub fn error(opcode: Opcode, request_id: u64, status: Status, message: &str) -> Frame {
        Frame {
            version: VERSION,
            opcode,
            status,
            request_id,
            payload: message.as_bytes().to_vec(),
        }
    }

    /// The same frame re-stamped with `version` (response echoing).
    pub fn at_version(mut self, version: u16) -> Frame {
        self.version = version;
        self
    }

    /// The payload as an error message (lossy UTF-8).
    pub fn message(&self) -> String {
        String::from_utf8_lossy(&self.payload).into_owned()
    }
}

/// Why a frame could not be read.
#[derive(Debug)]
pub enum ReadError {
    /// Transport failure.
    Io(std::io::Error),
    /// The bytes violate the protocol (bad magic/version/opcode,
    /// oversized payload, mid-frame EOF).
    Protocol(String),
    /// Clean EOF on a frame boundary (peer closed the connection).
    Eof,
    /// The caller's stop flag was raised while waiting for bytes.
    Stopped,
    /// The caller's read deadline passed before a full frame arrived —
    /// the peer is idle or dribbling a partial frame (slowloris).
    TimedOut,
}

impl std::fmt::Display for ReadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ReadError::Io(e) => write!(f, "io error: {e}"),
            ReadError::Protocol(m) => write!(f, "protocol error: {m}"),
            ReadError::Eof => write!(f, "connection closed"),
            ReadError::Stopped => write!(f, "stopped"),
            ReadError::TimedOut => write!(f, "read deadline exceeded"),
        }
    }
}

impl std::error::Error for ReadError {}

/// Serialize `frame` to `w` (single buffered write).
pub fn write_frame(w: &mut impl Write, frame: &Frame) -> std::io::Result<()> {
    let mut buf = Vec::with_capacity(HEADER_LEN + frame.payload.len());
    buf.extend_from_slice(&MAGIC);
    buf.extend_from_slice(&frame.version.to_le_bytes());
    buf.push(frame.opcode as u8);
    buf.push(frame.status as u8);
    buf.extend_from_slice(&frame.request_id.to_le_bytes());
    buf.extend_from_slice(&(frame.payload.len() as u32).to_le_bytes());
    buf.extend_from_slice(&frame.payload);
    w.write_all(&buf)
}

/// Read one frame, failing on payloads larger than `max_payload`.
/// Versions [`MIN_VERSION`]..=[`VERSION`] are accepted; the frame
/// records which one arrived.
pub fn read_frame(r: &mut impl Read, max_payload: u32) -> Result<Frame, ReadError> {
    read_frame_with(r, max_payload, None)
}

/// [`read_frame`] with an interruption flag: on sockets configured with
/// a read timeout, every timeout tick checks `stop` and returns
/// [`ReadError::Stopped`] once it is raised — how server connection
/// threads wind down without losing partially received frames.
pub fn read_frame_with(
    r: &mut impl Read,
    max_payload: u32,
    stop: Option<&AtomicBool>,
) -> Result<Frame, ReadError> {
    read_frame_deadline(r, max_payload, stop, None)
}

/// [`read_frame_with`] plus a hard read deadline: if `deadline` passes
/// before one complete frame has arrived, the read fails with
/// [`ReadError::TimedOut`]. The deadline is only observed on socket
/// read-timeout ticks, so the underlying reader must have a read
/// timeout set (the server uses `READ_TICK`) — granularity is one tick.
/// This is the slowloris defense: both a silent connection and one
/// dribbling a partial frame trip it.
pub fn read_frame_deadline(
    r: &mut impl Read,
    max_payload: u32,
    stop: Option<&AtomicBool>,
    deadline: Option<Instant>,
) -> Result<Frame, ReadError> {
    let mut header = [0u8; HEADER_LEN];
    read_full(r, &mut header, stop, deadline, true)?;
    let (version, opcode, status, request_id, len) =
        parse_header(&header, max_payload).map_err(ReadError::Protocol)?;
    let mut payload = vec![0u8; len];
    read_full(r, &mut payload, stop, deadline, false)?;
    Ok(Frame { version, opcode, status, request_id, payload })
}

/// Validate a complete wire header and return its fields. Checks run in
/// a fixed order (magic, version, opcode, status, payload cap) so every
/// framing path — the blocking readers above and the incremental
/// [`FrameAssembler`] — reports byte-identical diagnostics.
fn parse_header(
    header: &[u8; HEADER_LEN],
    max_payload: u32,
) -> Result<(u16, Opcode, Status, u64, usize), String> {
    if header[0..4] != MAGIC {
        return Err(format!("bad magic {:02x?}", &header[0..4]));
    }
    let version = u16::from_le_bytes([header[4], header[5]]);
    if !(MIN_VERSION..=VERSION).contains(&version) {
        return Err(format!(
            "unsupported protocol version {version} (supported {MIN_VERSION}..={VERSION})"
        ));
    }
    let opcode =
        Opcode::from_u8(header[6]).ok_or_else(|| format!("unknown opcode {}", header[6]))?;
    let status =
        Status::from_u8(header[7]).ok_or_else(|| format!("unknown status {}", header[7]))?;
    let request_id = u64::from_le_bytes(header[8..16].try_into().unwrap());
    let len = u32::from_le_bytes(header[16..20].try_into().unwrap());
    if len > max_payload {
        return Err(format!("payload length {len} exceeds cap {max_payload}"));
    }
    Ok((version, opcode, status, request_id, len as usize))
}

/// Incremental frame decoder for nonblocking sockets: feed it whatever
/// `read(2)` returned and pull complete frames out. Semantics are
/// byte-identical to [`read_frame_deadline`] over the same stream:
/// header fields are validated (via the shared [`parse_header`]) only
/// once all [`HEADER_LEN`] bytes have arrived, in the same order and
/// with the same diagnostic strings, and an EOF between frames is
/// distinguished from one mid-frame by [`FrameAssembler::is_mid_frame`].
#[derive(Debug, Default)]
pub struct FrameAssembler {
    buf: Vec<u8>,
    pos: usize,
}

impl FrameAssembler {
    pub fn new() -> Self {
        FrameAssembler { buf: Vec::new(), pos: 0 }
    }

    /// Append bytes received from the socket.
    pub fn push(&mut self, bytes: &[u8]) {
        // Compact lazily so a long-lived connection does not grow the
        // buffer by the total number of bytes it ever received.
        if self.pos == self.buf.len() {
            self.buf.clear();
            self.pos = 0;
        } else if self.pos > 4096 {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes buffered but not yet consumed as complete frames.
    pub fn buffered_len(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// True when a frame has partially arrived — an EOF now is a
    /// mid-frame truncation, not a clean close between frames.
    pub fn is_mid_frame(&self) -> bool {
        self.buffered_len() > 0
    }

    /// The diagnostic the blocking reader reports for a mid-frame EOF.
    pub fn eof_mid_frame() -> String {
        "connection closed mid-frame".to_string()
    }

    /// Try to extract the next complete frame. `Ok(None)` means more
    /// bytes are needed; errors carry the same diagnostics as
    /// [`read_frame_deadline`] and poison the stream (framing is
    /// unrecoverable once violated).
    pub fn next_frame(&mut self, max_payload: u32) -> Result<Option<Frame>, String> {
        let pending = &self.buf[self.pos..];
        if pending.len() < HEADER_LEN {
            return Ok(None);
        }
        let header: [u8; HEADER_LEN] = pending[..HEADER_LEN].try_into().unwrap();
        let (version, opcode, status, request_id, len) = parse_header(&header, max_payload)?;
        if pending.len() < HEADER_LEN + len {
            return Ok(None);
        }
        let payload = pending[HEADER_LEN..HEADER_LEN + len].to_vec();
        self.pos += HEADER_LEN + len;
        Ok(Some(Frame { version, opcode, status, request_id, payload }))
    }
}

/// `read_exact` that survives read-timeout ticks (checking `stop` and
/// the read `deadline` on each) and distinguishes boundary EOF from
/// mid-frame truncation.
fn read_full(
    r: &mut impl Read,
    buf: &mut [u8],
    stop: Option<&AtomicBool>,
    deadline: Option<Instant>,
    eof_ok_at_start: bool,
) -> Result<(), ReadError> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                return Err(if filled == 0 && eof_ok_at_start {
                    ReadError::Eof
                } else {
                    ReadError::Protocol("connection closed mid-frame".into())
                })
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                if let Some(s) = stop {
                    if s.load(Ordering::Relaxed) {
                        return Err(ReadError::Stopped);
                    }
                }
                if let Some(d) = deadline {
                    if Instant::now() >= d {
                        return Err(ReadError::TimedOut);
                    }
                }
                if stop.is_none() && deadline.is_none() {
                    return Err(ReadError::Io(e));
                }
                // timeout tick: keep waiting
            }
            Err(e) => return Err(ReadError::Io(e)),
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Payload codecs. All multi-byte values little-endian, mirroring the
// EMLP blob format in `util::serde`. The `decode_*` functions take the
// frame's version and parse the matching layout; v1 layouts carry no
// model names (the empty string routes to the server's default model)
// and pre-v3 layouts carry no QoS fields (defaulting to `Qos::NONE`).
// ---------------------------------------------------------------------------

/// Bounds-checked payload reader.
struct Buf<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Buf<'a> {
    fn new(bytes: &'a [u8]) -> Self {
        Buf { bytes, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.pos + n > self.bytes.len() {
            return Err(format!("truncated payload at byte {} (+{n})", self.pos));
        }
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u16(&mut self) -> Result<u16, String> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>, String> {
        Ok(self
            .take(n * 4)?
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// v2 model-name field: `u16 len | len UTF-8 bytes`, capped at
    /// [`MAX_MODEL_NAME_LEN`] *before* the bytes are read.
    fn name(&mut self) -> Result<String, String> {
        let len = self.u16()? as usize;
        if len > MAX_MODEL_NAME_LEN {
            return Err(format!("model name length {len} exceeds cap {MAX_MODEL_NAME_LEN}"));
        }
        String::from_utf8(self.take(len)?.to_vec())
            .map_err(|e| format!("model name not UTF-8: {e}"))
    }

    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    /// v3 QoS fields: `u64 deadline_us | u8 priority`, both validated.
    fn qos(&mut self) -> Result<Qos, String> {
        let deadline_us = self.u64()?;
        let raw = self.u8()?;
        let priority =
            Priority::from_u8(raw).ok_or_else(|| format!("unknown priority value {raw}"))?;
        let qos = Qos { deadline_us, priority };
        qos.validate()?;
        Ok(qos)
    }

    fn remaining(&self) -> usize {
        self.bytes.len() - self.pos
    }

    fn finish(&self) -> Result<(), String> {
        if self.pos != self.bytes.len() {
            return Err(format!("{} trailing payload bytes", self.bytes.len() - self.pos));
        }
        Ok(())
    }
}

fn push_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    out.reserve(xs.len() * 4);
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn push_name(out: &mut Vec<u8>, name: &str) -> Result<(), String> {
    if name.len() > MAX_MODEL_NAME_LEN {
        return Err(format!(
            "model name is {} bytes (cap {MAX_MODEL_NAME_LEN})",
            name.len()
        ));
    }
    out.extend_from_slice(&(name.len() as u16).to_le_bytes());
    out.extend_from_slice(name.as_bytes());
    Ok(())
}

fn push_qos(out: &mut Vec<u8>, qos: Qos) -> Result<(), String> {
    qos.validate()?;
    out.extend_from_slice(&qos.deadline_us.to_le_bytes());
    out.push(qos.priority as u8);
    Ok(())
}

/// A decoded `Infer` request payload.
#[derive(Debug, Clone, PartialEq)]
pub struct InferReq {
    pub backend: u32,
    /// Empty = the server's default model (always empty for v1).
    pub model: String,
    /// `Qos::NONE` for every pre-v3 payload.
    pub qos: Qos,
    pub x: Vec<f32>,
}

/// Shared body of the v1/v2/v3 `Infer` encoders: `model` is present in
/// v2+ payloads, `qos` in v3 payloads only.
fn encode_infer_body(
    backend: u32,
    model: Option<&str>,
    qos: Option<Qos>,
    x: &[f32],
) -> Result<Vec<u8>, String> {
    let mut out = Vec::with_capacity(19 + model.map_or(0, str::len) + x.len() * 4);
    out.extend_from_slice(&backend.to_le_bytes());
    if let Some(model) = model {
        push_name(&mut out, model)?;
    }
    if let Some(qos) = qos {
        push_qos(&mut out, qos)?;
    }
    out.extend_from_slice(&(x.len() as u32).to_le_bytes());
    push_f32s(&mut out, x);
    Ok(out)
}

/// v3 `Infer` request payload with explicit QoS: `u32 backend |
/// u16 model_len | model | u64 deadline_us | u8 priority | u32 dim |
/// dim × f32`. The empty model name routes to the server's default
/// model.
pub fn encode_infer_qos(
    backend: u32,
    model: &str,
    qos: Qos,
    x: &[f32],
) -> Result<Vec<u8>, String> {
    encode_infer_body(backend, Some(model), Some(qos), x)
}

/// v3 `Infer` request payload with default QoS (no deadline, normal
/// priority) — the common case, and what [`Frame::ok`]'s `VERSION`
/// stamp expects.
pub fn encode_infer(backend: u32, model: &str, x: &[f32]) -> Result<Vec<u8>, String> {
    encode_infer_qos(backend, model, Qos::NONE, x)
}

/// v2 `Infer` request payload (no QoS fields):
/// `u32 backend | u16 model_len | model | u32 dim | dim × f32`.
pub fn encode_infer_v2(backend: u32, model: &str, x: &[f32]) -> Result<Vec<u8>, String> {
    encode_infer_body(backend, Some(model), None, x)
}

/// v1 `Infer` request payload: `u32 backend | u32 dim | dim × f32`.
pub fn encode_infer_v1(backend: u32, x: &[f32]) -> Vec<u8> {
    encode_infer_body(backend, None, None, x).expect("nameless encoding is infallible")
}

/// Decode an `Infer` payload framed at `version`. v1 payloads resolve
/// to the empty (default) model name; pre-v3 payloads to `Qos::NONE`.
pub fn decode_infer(payload: &[u8], version: u16) -> Result<InferReq, String> {
    let mut b = Buf::new(payload);
    let backend = b.u32()?;
    let model = if version >= 2 { b.name()? } else { String::new() };
    let qos = if version >= 3 { b.qos()? } else { Qos::NONE };
    let dim = b.u32()? as usize;
    let x = b.f32s(dim)?;
    b.finish()?;
    Ok(InferReq { backend, model, qos, x })
}

/// A decoded `InferBatch` request payload.
#[derive(Debug, Clone, PartialEq)]
pub struct InferBatchReq {
    pub backend: u32,
    /// Empty = the server's default model (always empty for v1).
    pub model: String,
    /// One QoS for the whole batch; `Qos::NONE` for pre-v3 payloads.
    pub qos: Qos,
    pub samples: Vec<Vec<f32>>,
}

/// Shared body of the v1/v2/v3 `InferBatch` encoders — one place for
/// the ragged-batch validation so the versions cannot diverge.
fn encode_infer_batch_body(
    backend: u32,
    model: Option<&str>,
    qos: Option<Qos>,
    samples: &[Vec<f32>],
) -> Result<Vec<u8>, String> {
    let dim = samples.first().map(|s| s.len()).unwrap_or(0);
    if samples.iter().any(|s| s.len() != dim) {
        return Err("ragged batch: samples differ in dimension".into());
    }
    let mut out =
        Vec::with_capacity(23 + model.map_or(0, str::len) + samples.len() * dim * 4);
    out.extend_from_slice(&backend.to_le_bytes());
    if let Some(model) = model {
        push_name(&mut out, model)?;
    }
    if let Some(qos) = qos {
        push_qos(&mut out, qos)?;
    }
    out.extend_from_slice(&(samples.len() as u32).to_le_bytes());
    out.extend_from_slice(&(dim as u32).to_le_bytes());
    for s in samples {
        push_f32s(&mut out, s);
    }
    Ok(out)
}

/// v3 `InferBatch` request payload with explicit QoS:
/// `u32 backend | u16 model_len | model | u64 deadline_us | u8 priority
/// | u32 batch | u32 dim | batch × dim × f32`.
pub fn encode_infer_batch_qos(
    backend: u32,
    model: &str,
    qos: Qos,
    samples: &[Vec<f32>],
) -> Result<Vec<u8>, String> {
    encode_infer_batch_body(backend, Some(model), Some(qos), samples)
}

/// v3 `InferBatch` request payload with default QoS.
pub fn encode_infer_batch(
    backend: u32,
    model: &str,
    samples: &[Vec<f32>],
) -> Result<Vec<u8>, String> {
    encode_infer_batch_qos(backend, model, Qos::NONE, samples)
}

/// v2 `InferBatch` request payload (no QoS fields):
/// `u32 backend | u16 model_len | model | u32 batch | u32 dim | batch × dim × f32`.
pub fn encode_infer_batch_v2(
    backend: u32,
    model: &str,
    samples: &[Vec<f32>],
) -> Result<Vec<u8>, String> {
    encode_infer_batch_body(backend, Some(model), None, samples)
}

/// v1 `InferBatch` request payload:
/// `u32 backend | u32 batch | u32 dim | batch × dim × f32`.
pub fn encode_infer_batch_v1(backend: u32, samples: &[Vec<f32>]) -> Result<Vec<u8>, String> {
    encode_infer_batch_body(backend, None, None, samples)
}

/// Decode an `InferBatch` payload framed at `version`.
pub fn decode_infer_batch(payload: &[u8], version: u16) -> Result<InferBatchReq, String> {
    let mut b = Buf::new(payload);
    let backend = b.u32()?;
    let model = if version >= 2 { b.name()? } else { String::new() };
    let qos = if version >= 3 { b.qos()? } else { Qos::NONE };
    let batch = b.u32()? as usize;
    let dim = b.u32()? as usize;
    check_grid(batch, dim, b.remaining())?;
    let mut samples = Vec::with_capacity(batch);
    for _ in 0..batch {
        samples.push(b.f32s(dim)?);
    }
    b.finish()?;
    Ok(InferBatchReq { backend, model, qos, samples })
}

/// Reject a declared `batch × dim` geometry that does not match the
/// bytes actually present — BEFORE any batch-sized allocation, so a
/// hostile 12-byte header cannot request a multi-gigabyte `Vec`.
fn check_grid(batch: usize, dim: usize, remaining: usize) -> Result<(), String> {
    if batch == 0 || dim == 0 {
        return Err(format!("degenerate batch geometry {batch}×{dim}"));
    }
    let expected = (batch as u64) * (dim as u64) * 4;
    if expected != remaining as u64 {
        return Err(format!(
            "batch {batch} × dim {dim} needs {expected} payload bytes, have {remaining}"
        ));
    }
    Ok(())
}

/// `Infer` response payload: `u32 dim | dim × f32`.
pub fn encode_outputs(out: &[f32]) -> Vec<u8> {
    let mut buf = Vec::with_capacity(4 + out.len() * 4);
    buf.extend_from_slice(&(out.len() as u32).to_le_bytes());
    push_f32s(&mut buf, out);
    buf
}

pub fn decode_outputs(payload: &[u8]) -> Result<Vec<f32>, String> {
    let mut b = Buf::new(payload);
    let dim = b.u32()? as usize;
    let out = b.f32s(dim)?;
    b.finish()?;
    Ok(out)
}

/// `InferBatch` response payload: `u32 batch | u32 dim | batch × dim × f32`.
pub fn encode_batch_outputs(rows: &[Vec<f32>]) -> Vec<u8> {
    let dim = rows.first().map(|r| r.len()).unwrap_or(0);
    debug_assert!(rows.iter().all(|r| r.len() == dim), "ragged outputs");
    let mut buf = Vec::with_capacity(8 + rows.len() * dim * 4);
    buf.extend_from_slice(&(rows.len() as u32).to_le_bytes());
    buf.extend_from_slice(&(dim as u32).to_le_bytes());
    for r in rows {
        push_f32s(&mut buf, r);
    }
    buf
}

pub fn decode_batch_outputs(payload: &[u8]) -> Result<Vec<Vec<f32>>, String> {
    let mut b = Buf::new(payload);
    let batch = b.u32()? as usize;
    let dim = b.u32()? as usize;
    check_grid(batch, dim, b.remaining())?;
    let mut rows = Vec::with_capacity(batch);
    for _ in 0..batch {
        rows.push(b.f32s(dim)?);
    }
    b.finish()?;
    Ok(rows)
}

/// Length-prefixed UTF-8 string — the v1 `SwapModel` request payload.
pub fn encode_str(s: &str) -> Vec<u8> {
    let mut buf = Vec::with_capacity(4 + s.len());
    buf.extend_from_slice(&(s.len() as u32).to_le_bytes());
    buf.extend_from_slice(s.as_bytes());
    buf
}

pub fn decode_str(payload: &[u8]) -> Result<String, String> {
    let mut b = Buf::new(payload);
    let len = b.u32()? as usize;
    let s = String::from_utf8(b.take(len)?.to_vec()).map_err(|e| e.to_string())?;
    b.finish()?;
    Ok(s)
}

/// v2 `SwapModel` request payload: `u16 slot_len | slot | u16 src_len |
/// src` — activate registered model `src` into serving slot `slot`
/// (empty slot = the server's default slot).
pub fn encode_swap(slot: &str, source: &str) -> Result<Vec<u8>, String> {
    let mut out = Vec::with_capacity(4 + slot.len() + source.len());
    push_name(&mut out, slot)?;
    push_name(&mut out, source)?;
    Ok(out)
}

/// [`encode_swap`] plus the v4 suffix extension: an optional trailing
/// [`Precision`] byte pinning the slot's serving precision. `None`
/// encodes exactly the v2 layout, so the payload stays decodable by
/// pre-v4 servers.
pub fn encode_swap_precision(
    slot: &str,
    source: &str,
    precision: Option<Precision>,
) -> Result<Vec<u8>, String> {
    let mut out = encode_swap(slot, source)?;
    if let Some(p) = precision {
        out.push(p.as_u8());
    }
    Ok(out)
}

/// Decode a `SwapModel` payload framed at `version` into
/// `(slot, source)`. The v1 single-string form targets the default
/// slot (empty slot name). A trailing precision byte (v4) is accepted
/// and discarded — servers that act on it use
/// [`decode_swap_precision`].
pub fn decode_swap(payload: &[u8], version: u16) -> Result<(String, String), String> {
    let (slot, source, _precision) = decode_swap_precision(payload, version)?;
    Ok((slot, source))
}

/// [`decode_swap`] plus the v4 precision extension: one optional
/// trailing byte selecting the slot's serving precision. Only v4
/// framing may carry it — on v2/v3 payloads a trailing byte fails the
/// exact-length check (`BadRequest`, never a panic), and an unknown
/// precision value is rejected by name.
pub fn decode_swap_precision(
    payload: &[u8],
    version: u16,
) -> Result<(String, String, Option<Precision>), String> {
    if version >= 2 {
        let mut b = Buf::new(payload);
        let slot = b.name()?;
        let source = b.name()?;
        let precision = if version >= 4 && b.remaining() > 0 {
            let raw = b.u8()?;
            Some(
                Precision::from_u8(raw)
                    .ok_or_else(|| format!("unknown precision value {raw}"))?,
            )
        } else {
            None
        };
        b.finish()?;
        Ok((slot, source, precision))
    } else {
        Ok((String::new(), decode_str(payload)?, None))
    }
}

/// One entry of a `ListModels` response: a serving slot and the model
/// version currently active in it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelInfo {
    /// Slot name clients route by (the `model` field of `Infer`).
    pub slot: String,
    /// Name of the catalog model active in the slot.
    pub model: String,
    /// Version of the active model.
    pub version: u32,
    pub input_dim: u32,
    pub output_dim: u32,
    /// The slot's swap generation (bumped per activation).
    pub generation: u64,
    /// Numeric precision the slot serves at (v4 extension;
    /// [`Precision::F32`] when decoding a pre-v4 payload).
    pub precision: Precision,
}

/// `ListModels` response payload at the current version — see
/// [`encode_model_list_at`].
pub fn encode_model_list(models: &[ModelInfo]) -> Result<Vec<u8>, String> {
    encode_model_list_at(models, VERSION)
}

/// `ListModels` response payload: `u32 count | count × (u16 slot_len |
/// slot | u16 model_len | model | u32 version | u32 input_dim |
/// u32 output_dim | u64 generation)`, followed (v4+ framing only) by a
/// suffix extension of `count` [`Precision`] bytes, one per entry in
/// table order. Pre-v4 framing omits the suffix so old clients decode
/// exactly the bytes they always did.
pub fn encode_model_list_at(models: &[ModelInfo], version: u16) -> Result<Vec<u8>, String> {
    let mut out = Vec::new();
    out.extend_from_slice(&(models.len() as u32).to_le_bytes());
    for m in models {
        push_name(&mut out, &m.slot)?;
        push_name(&mut out, &m.model)?;
        out.extend_from_slice(&m.version.to_le_bytes());
        out.extend_from_slice(&m.input_dim.to_le_bytes());
        out.extend_from_slice(&m.output_dim.to_le_bytes());
        out.extend_from_slice(&m.generation.to_le_bytes());
    }
    if version >= 4 {
        for m in models {
            out.push(m.precision.as_u8());
        }
    }
    Ok(out)
}

pub fn decode_model_list(payload: &[u8]) -> Result<Vec<ModelInfo>, String> {
    let mut b = Buf::new(payload);
    let count = b.u32()? as usize;
    // Each entry is at least 24 bytes; reject a hostile count before
    // allocating for it.
    if (count as u64) * 24 > payload.len() as u64 {
        return Err(format!("model count {count} exceeds payload size"));
    }
    let mut models = Vec::with_capacity(count);
    for _ in 0..count {
        models.push(ModelInfo {
            slot: b.name()?,
            model: b.name()?,
            version: b.u32()?,
            input_dim: b.u32()?,
            output_dim: b.u32()?,
            generation: b.u64()?,
            precision: Precision::F32,
        });
    }
    // v4 precision suffix, present iff bytes remain after the entry
    // table — pre-v4 payloads end exactly here. A partial suffix is
    // malformed: it is all entries or none.
    if b.remaining() > 0 {
        if b.remaining() != count {
            return Err(format!(
                "precision suffix has {} bytes for {count} models",
                b.remaining()
            ));
        }
        for m in models.iter_mut() {
            let raw = b.u8()?;
            m.precision = Precision::from_u8(raw)
                .ok_or_else(|| format!("unknown precision value {raw}"))?;
        }
    }
    b.finish()?;
    Ok(models)
}

/// One pool's slice of a `Health` response.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PoolHealth {
    /// Pool label (`"<backend>/<slot>"`).
    pub name: String,
    /// Requests currently queued (instantaneous).
    pub queue_depth: u32,
    /// The queue's bound — depth/capacity is the occupancy signal the
    /// degraded-mode controller watches.
    pub queue_capacity: u32,
    pub replicas: u32,
    /// Requests shed at admission because the queue was full.
    pub shed: u64,
    /// Requests answered `Expired` (admission reject + in-queue expiry).
    pub expired: u64,
}

/// `Health` (v3) response body: the resilience counters a load balancer
/// or operator polls to see shedding and degradation as they happen.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct HealthReport {
    /// True while degraded-mode routing is active for any model.
    pub degraded: bool,
    /// Mode flips (normal→degraded and back) since startup.
    pub degraded_transitions: u64,
    /// Connections closed by the server's read deadline (slowloris).
    pub read_timeouts: u64,
    pub pools: Vec<PoolHealth>,
    /// Connections turned away with `Busy` at accept time (v4
    /// extension; 0 when decoding a pre-v4 payload).
    pub busy_rejected: u64,
    /// `BadRequest` answers by cause label (v4 extension; empty when
    /// decoding a pre-v4 payload).
    pub bad_requests: Vec<(String, u64)>,
}

/// `Health` response payload: `u8 degraded | u64 transitions |
/// u64 read_timeouts | u32 count | count × (u16 name_len | name |
/// u32 depth | u32 capacity | u32 replicas | u64 shed | u64 expired)`,
/// followed (v4+ framing only) by an extension block
/// `u64 busy_rejected | u32 cause_count | count × (u16 len | cause |
/// u64 n)`. The request payload is empty.
pub fn encode_health(report: &HealthReport) -> Result<Vec<u8>, String> {
    encode_health_at(report, VERSION)
}

/// [`encode_health`] framed for `version`: pre-v4 payloads omit the
/// extension block so old clients decode exactly the bytes they
/// always did.
pub fn encode_health_at(report: &HealthReport, version: u16) -> Result<Vec<u8>, String> {
    let mut out = Vec::with_capacity(33 + report.pools.len() * 32);
    out.push(report.degraded as u8);
    out.extend_from_slice(&report.degraded_transitions.to_le_bytes());
    out.extend_from_slice(&report.read_timeouts.to_le_bytes());
    out.extend_from_slice(&(report.pools.len() as u32).to_le_bytes());
    for p in &report.pools {
        push_name(&mut out, &p.name)?;
        out.extend_from_slice(&p.queue_depth.to_le_bytes());
        out.extend_from_slice(&p.queue_capacity.to_le_bytes());
        out.extend_from_slice(&p.replicas.to_le_bytes());
        out.extend_from_slice(&p.shed.to_le_bytes());
        out.extend_from_slice(&p.expired.to_le_bytes());
    }
    if version >= 4 {
        out.extend_from_slice(&report.busy_rejected.to_le_bytes());
        out.extend_from_slice(&(report.bad_requests.len() as u32).to_le_bytes());
        for (cause, n) in &report.bad_requests {
            push_name(&mut out, cause)?;
            out.extend_from_slice(&n.to_le_bytes());
        }
    }
    Ok(out)
}

pub fn decode_health(payload: &[u8]) -> Result<HealthReport, String> {
    decode_health_loop(payload).map(|(report, _)| report)
}

/// Event-loop gauges the server appends to v4 `Health` responses as a
/// trailing block after the v4 extension: a point-in-time view of the
/// readiness loop (docs/async-net.md). Like the v4 extension itself,
/// the block is present iff bytes remain — pre-loop payloads decode to
/// `None`, and truncation inside the block is malformed.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct LoopGauges {
    /// Sockets currently registered with the poller (serving +
    /// draining connections; the listener and waker are excluded).
    pub registered_conns: u64,
    /// Readiness events delivered by the poller since startup.
    pub ready_events: u64,
    /// Poller wakeups (event batches + timer ticks) since startup.
    pub poll_ticks: u64,
    /// Response bytes accepted from the coordinator but not yet
    /// flushed to sockets.
    pub pending_writeback_bytes: u64,
    /// Live timer-wheel entries (read deadlines + drain budgets).
    pub timer_depth: u64,
}

/// [`encode_health_at`] plus the trailing [`LoopGauges`] block
/// (`5 × u64`, v4+ framing only — pre-v4 payloads are byte-identical
/// to [`encode_health_at`]).
pub fn encode_health_loop(
    report: &HealthReport,
    gauges: &LoopGauges,
    version: u16,
) -> Result<Vec<u8>, String> {
    let mut out = encode_health_at(report, version)?;
    if version >= 4 {
        out.extend_from_slice(&gauges.registered_conns.to_le_bytes());
        out.extend_from_slice(&gauges.ready_events.to_le_bytes());
        out.extend_from_slice(&gauges.poll_ticks.to_le_bytes());
        out.extend_from_slice(&gauges.pending_writeback_bytes.to_le_bytes());
        out.extend_from_slice(&gauges.timer_depth.to_le_bytes());
    }
    Ok(out)
}

/// Autoscaler state the server appends to v4 `Health` responses as a
/// trailing block after [`LoopGauges`]. Like the blocks before it, the
/// block is present iff bytes remain — payloads from servers without
/// the autoscaler end exactly at the loop gauges, and truncation inside
/// the block is malformed. A non-autoscaling server that *does* send
/// the block marks it `enabled = false` with zeroed counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct AutoscaleHealth {
    /// True when an autoscaler thread is running.
    pub enabled: bool,
    /// Configured replica floor per scalable pool.
    pub min_replicas: u32,
    /// Configured replica ceiling per scalable pool.
    pub max_replicas: u32,
    /// Replica-add actions taken since startup.
    pub scale_ups: u64,
    /// Replica-retire actions taken since startup.
    pub scale_downs: u64,
    /// Modeled board draw at the last sample, milliwatts.
    pub power_mw: u64,
    /// Configured power budget, milliwatts (0 = no budget).
    pub budget_mw: u64,
    /// True while the power budget holds degraded routing latched.
    pub power_degraded: bool,
}

/// [`encode_health_loop`] plus the trailing [`AutoscaleHealth`] block
/// (`u8 enabled | u32 min | u32 max | u64 ups | u64 downs |
/// u64 power_mw | u64 budget_mw | u8 power_degraded`, v4+ framing only).
pub fn encode_health_full(
    report: &HealthReport,
    gauges: &LoopGauges,
    autoscale: &AutoscaleHealth,
    version: u16,
) -> Result<Vec<u8>, String> {
    let mut out = encode_health_loop(report, gauges, version)?;
    if version >= 4 {
        out.push(autoscale.enabled as u8);
        out.extend_from_slice(&autoscale.min_replicas.to_le_bytes());
        out.extend_from_slice(&autoscale.max_replicas.to_le_bytes());
        out.extend_from_slice(&autoscale.scale_ups.to_le_bytes());
        out.extend_from_slice(&autoscale.scale_downs.to_le_bytes());
        out.extend_from_slice(&autoscale.power_mw.to_le_bytes());
        out.extend_from_slice(&autoscale.budget_mw.to_le_bytes());
        out.push(autoscale.power_degraded as u8);
    }
    Ok(out)
}

/// [`decode_health_loop`] that also surfaces the trailing
/// [`AutoscaleHealth`] block when the server sent one (`None` for
/// payloads from servers without the autoscaler).
pub fn decode_health_full(
    payload: &[u8],
) -> Result<(HealthReport, Option<LoopGauges>, Option<AutoscaleHealth>), String> {
    decode_health_parts(payload)
}

/// [`decode_health`] that also surfaces the trailing [`LoopGauges`]
/// block when the server sent one (`None` for pre-loop payloads).
pub fn decode_health_loop(payload: &[u8]) -> Result<(HealthReport, Option<LoopGauges>), String> {
    decode_health_parts(payload).map(|(report, gauges, _)| (report, gauges))
}

fn decode_health_parts(
    payload: &[u8],
) -> Result<(HealthReport, Option<LoopGauges>, Option<AutoscaleHealth>), String> {
    let mut b = Buf::new(payload);
    let degraded = match b.u8()? {
        0 => false,
        1 => true,
        other => return Err(format!("bad degraded flag {other}")),
    };
    let degraded_transitions = b.u64()?;
    let read_timeouts = b.u64()?;
    let count = b.u32()? as usize;
    // Each entry is at least 30 bytes; reject a hostile count before
    // allocating for it.
    if (count as u64) * 30 > payload.len() as u64 {
        return Err(format!("pool count {count} exceeds payload size"));
    }
    let mut pools = Vec::with_capacity(count);
    for _ in 0..count {
        pools.push(PoolHealth {
            name: b.name()?,
            queue_depth: b.u32()?,
            queue_capacity: b.u32()?,
            replicas: b.u32()?,
            shed: b.u64()?,
            expired: b.u64()?,
        });
    }
    // v4 extension block, present iff bytes remain after the pools —
    // pre-v4 payloads end exactly here.
    let (busy_rejected, bad_requests) = if b.remaining() > 0 {
        let busy = b.u64()?;
        let cause_count = b.u32()? as usize;
        // Each entry is at least 10 bytes; reject a hostile count
        // before allocating for it.
        if (cause_count as u64) * 10 > b.remaining() as u64 {
            return Err(format!("cause count {cause_count} exceeds payload size"));
        }
        let mut causes = Vec::with_capacity(cause_count);
        for _ in 0..cause_count {
            causes.push((b.name()?, b.u64()?));
        }
        (busy, causes)
    } else {
        (0, Vec::new())
    };
    // Loop-gauge block, present iff bytes remain after the extension —
    // payloads from servers without the readiness loop end exactly here.
    let gauges = if b.remaining() > 0 {
        Some(LoopGauges {
            registered_conns: b.u64()?,
            ready_events: b.u64()?,
            poll_ticks: b.u64()?,
            pending_writeback_bytes: b.u64()?,
            timer_depth: b.u64()?,
        })
    } else {
        None
    };
    // Autoscale block, present iff bytes remain after the loop gauges —
    // payloads from servers without the autoscaler end exactly here.
    let autoscale = if b.remaining() > 0 {
        let enabled = match b.u8()? {
            0 => false,
            1 => true,
            other => return Err(format!("bad autoscale enabled flag {other}")),
        };
        let min_replicas = b.u32()?;
        let max_replicas = b.u32()?;
        let scale_ups = b.u64()?;
        let scale_downs = b.u64()?;
        let power_mw = b.u64()?;
        let budget_mw = b.u64()?;
        let power_degraded = match b.u8()? {
            0 => false,
            1 => true,
            other => return Err(format!("bad autoscale degraded flag {other}")),
        };
        Some(AutoscaleHealth {
            enabled,
            min_replicas,
            max_replicas,
            scale_ups,
            scale_downs,
            power_mw,
            budget_mw,
            power_degraded,
        })
    } else {
        None
    };
    b.finish()?;
    Ok((
        HealthReport {
            degraded,
            degraded_transitions,
            read_timeouts,
            pools,
            busy_rejected,
            bad_requests,
        },
        gauges,
        autoscale,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn roundtrip(frame: &Frame) -> Frame {
        let mut buf = Vec::new();
        write_frame(&mut buf, frame).unwrap();
        read_frame(&mut Cursor::new(buf), DEFAULT_MAX_PAYLOAD).unwrap()
    }

    #[test]
    fn frame_roundtrip() {
        let f = Frame::ok(Opcode::Infer, 42, encode_infer(0, "mnist", &[1.0, -2.5]).unwrap());
        assert_eq!(roundtrip(&f), f);
        let e = Frame::error(Opcode::SwapModel, 7, Status::UnknownModel, "no such model");
        let back = roundtrip(&e);
        assert_eq!(back.status, Status::UnknownModel);
        assert_eq!(back.message(), "no such model");
    }

    #[test]
    fn v1_frames_still_read() {
        let f = Frame::ok(Opcode::Infer, 3, encode_infer_v1(0, &[1.0])).at_version(1);
        let back = roundtrip(&f);
        assert_eq!(back.version, 1);
        assert_eq!(back, f);
    }

    #[test]
    fn empty_payload_frame() {
        let f = Frame::ok(Opcode::Stats, 1, Vec::new());
        assert_eq!(roundtrip(&f), f);
    }

    #[test]
    fn bad_magic_rejected() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::ok(Opcode::Ping, 0, Vec::new())).unwrap();
        buf[0] = b'X';
        match read_frame(&mut Cursor::new(buf), DEFAULT_MAX_PAYLOAD) {
            Err(ReadError::Protocol(m)) => assert!(m.contains("magic"), "{m}"),
            other => panic!("expected protocol error, got {other:?}"),
        }
    }

    #[test]
    fn wrong_version_rejected() {
        for bad in [0u16, 5, 99] {
            let mut buf = Vec::new();
            write_frame(&mut buf, &Frame::ok(Opcode::Ping, 0, Vec::new())).unwrap();
            buf[4..6].copy_from_slice(&bad.to_le_bytes());
            assert!(
                matches!(
                    read_frame(&mut Cursor::new(buf), DEFAULT_MAX_PAYLOAD),
                    Err(ReadError::Protocol(_))
                ),
                "version {bad} accepted"
            );
        }
    }

    #[test]
    fn unknown_opcode_rejected() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::ok(Opcode::Ping, 0, Vec::new())).unwrap();
        buf[6] = 200;
        assert!(matches!(
            read_frame(&mut Cursor::new(buf), DEFAULT_MAX_PAYLOAD),
            Err(ReadError::Protocol(_))
        ));
    }

    #[test]
    fn oversized_payload_rejected_before_allocation() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::ok(Opcode::Ping, 0, vec![0u8; 64])).unwrap();
        // Read with a cap below the declared length.
        match read_frame(&mut Cursor::new(buf), 16) {
            Err(ReadError::Protocol(m)) => assert!(m.contains("exceeds cap"), "{m}"),
            other => panic!("expected protocol error, got {other:?}"),
        }
    }

    #[test]
    fn clean_eof_vs_truncation() {
        assert!(matches!(
            read_frame(&mut Cursor::new(Vec::<u8>::new()), 1024),
            Err(ReadError::Eof)
        ));
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::ok(Opcode::Ping, 0, vec![1, 2, 3])).unwrap();
        buf.truncate(buf.len() - 2);
        assert!(matches!(
            read_frame(&mut Cursor::new(buf), 1024),
            Err(ReadError::Protocol(_))
        ));
    }

    #[test]
    fn infer_payload_roundtrip_all_versions() {
        let x = vec![0.25f32, -1.0, 3.5];
        // v3 with explicit QoS.
        let qos = Qos { deadline_us: 50_000, priority: Priority::High };
        let req =
            decode_infer(&encode_infer_qos(BACKEND_ANY, "qnet", qos, &x).unwrap(), 3).unwrap();
        assert_eq!(req.backend, BACKEND_ANY);
        assert_eq!(req.model, "qnet");
        assert_eq!(req.qos, qos);
        assert_eq!(req.x, x);
        // v3 default QoS (the plain encoder).
        let req = decode_infer(&encode_infer(BACKEND_ANY, "qnet", &x).unwrap(), 3).unwrap();
        assert_eq!(req.qos, Qos::NONE);
        assert!(!req.qos.has_deadline());
        // v2: no QoS fields, defaults to none.
        let req = decode_infer(&encode_infer_v2(BACKEND_ANY, "qnet", &x).unwrap(), 2).unwrap();
        assert_eq!(req.model, "qnet");
        assert_eq!(req.qos, Qos::NONE);
        assert_eq!(req.x, x);
        // v1: no model field, resolves to the default model.
        let req = decode_infer(&encode_infer_v1(0, &x), 1).unwrap();
        assert_eq!(req.backend, 0);
        assert_eq!(req.model, "");
        assert_eq!(req.qos, Qos::NONE);
        assert_eq!(req.x, x);
        // Trailing garbage rejected.
        let mut p = encode_infer(0, "", &x).unwrap();
        p.push(0);
        assert!(decode_infer(&p, 3).is_err());
    }

    #[test]
    fn qos_field_validation() {
        let x = vec![1.0f32];
        // Unknown priority byte rejected.
        let mut p = encode_infer_qos(0, "", Qos::NONE, &x).unwrap();
        // Layout: backend(4) | name_len(2) | deadline(8) | priority(1)…
        p[14] = 9;
        let err = decode_infer(&p, 3).unwrap_err();
        assert!(err.contains("priority"), "{err}");
        // Absurd deadline rejected by both encoder and decoder.
        let absurd = Qos::with_deadline_us(MAX_DEADLINE_US + 1);
        assert!(encode_infer_qos(0, "", absurd, &x).is_err());
        let mut p = encode_infer_qos(0, "", Qos::NONE, &x).unwrap();
        p[6..14].copy_from_slice(&u64::MAX.to_le_bytes());
        let err = decode_infer(&p, 3).unwrap_err();
        assert!(err.contains("deadline"), "{err}");
        // Truncated QoS fields are a truncated payload, not a panic.
        let good = encode_infer_qos(0, "", Qos::with_deadline_us(1000), &x).unwrap();
        for cut in 7..15 {
            assert!(decode_infer(&good[..cut], 3).is_err(), "cut at {cut}");
        }
        // The deadline cap itself is encodable.
        let max = Qos::with_deadline_us(MAX_DEADLINE_US);
        let p = encode_infer_qos(0, "", max, &x).unwrap();
        assert_eq!(decode_infer(&p, 3).unwrap().qos, max);
    }

    #[test]
    fn infer_batch_payload_roundtrip_all_versions() {
        let samples = vec![vec![1.0f32, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]];
        let qos = Qos { deadline_us: 2_000, priority: Priority::Low };
        let payload = encode_infer_batch_qos(2, "mnist", qos, &samples).unwrap();
        let req = decode_infer_batch(&payload, 3).unwrap();
        assert_eq!(req.backend, 2);
        assert_eq!(req.model, "mnist");
        assert_eq!(req.qos, qos);
        assert_eq!(req.samples, samples);
        let payload = encode_infer_batch_v2(2, "mnist", &samples).unwrap();
        let req = decode_infer_batch(&payload, 2).unwrap();
        assert_eq!(req.model, "mnist");
        assert_eq!(req.qos, Qos::NONE);
        assert_eq!(req.samples, samples);
        let payload = encode_infer_batch_v1(1, &samples).unwrap();
        let req = decode_infer_batch(&payload, 1).unwrap();
        assert_eq!(req.backend, 1);
        assert_eq!(req.model, "");
        assert_eq!(req.samples, samples);
        assert!(encode_infer_batch(0, "", &[vec![1.0], vec![1.0, 2.0]]).is_err());
        assert!(
            decode_infer_batch(&encode_infer_batch(0, "", &[]).unwrap(), 3).is_err()
        );
    }

    #[test]
    fn model_name_length_is_capped() {
        let long = "m".repeat(MAX_MODEL_NAME_LEN + 1);
        assert!(encode_infer(0, &long, &[1.0]).is_err());
        let ok = "m".repeat(MAX_MODEL_NAME_LEN);
        let p = encode_infer(0, &ok, &[1.0]).unwrap();
        assert_eq!(decode_infer(&p, 3).unwrap().model, ok);
    }

    #[test]
    fn malformed_model_name_lengths_never_panic() {
        // Property sweep: every u16 name-length value spliced into an
        // otherwise valid v2 Infer payload either decodes cleanly (the
        // true length) or errors — truncated names, oversized lengths
        // and lengths pointing past the payload all included.
        let x = vec![0.5f32; 4];
        let good = encode_infer(0, "model", &x).unwrap();
        for lied in 0..=u16::MAX {
            let mut p = good.clone();
            p[4..6].copy_from_slice(&lied.to_le_bytes());
            match decode_infer(&p, 3) {
                Ok(req) => {
                    assert_eq!(lied, 5, "length {lied} decoded");
                    assert_eq!(req.model, "model");
                    assert_eq!(req.x, x);
                }
                Err(msg) => assert!(!msg.is_empty()),
            }
        }
        // Same splice on InferBatch.
        let goodb = encode_infer_batch(0, "model", &[x.clone(), x]).unwrap();
        for lied in [0u16, 1, 4, 6, 200, 255, 256, 1000, u16::MAX] {
            let mut p = goodb.clone();
            p[4..6].copy_from_slice(&lied.to_le_bytes());
            match decode_infer_batch(&p, 3) {
                Ok(req) => {
                    assert_eq!(lied, 5);
                    assert_eq!(req.model, "model");
                }
                Err(msg) => assert!(!msg.is_empty()),
            }
        }
    }

    #[test]
    fn hostile_batch_header_rejected_before_allocation() {
        // batch = u32::MAX with dim = 0 in a 12-byte payload must be
        // rejected up front, not via a ~4-billion-element Vec.
        let mut p = Vec::new();
        p.extend_from_slice(&0u32.to_le_bytes());
        p.extend_from_slice(&u32::MAX.to_le_bytes());
        p.extend_from_slice(&0u32.to_le_bytes());
        assert!(decode_infer_batch(&p, 1).is_err());
        // Declared geometry must match the byte count actually present.
        let mut q = encode_infer_batch_v1(0, &[vec![1.0f32; 4], vec![2.0f32; 4]]).unwrap();
        q[4..8].copy_from_slice(&100u32.to_le_bytes()); // lie about batch
        assert!(decode_infer_batch(&q, 1).is_err());
        // Same guard on the response decoder (malicious server).
        let mut r = Vec::new();
        r.extend_from_slice(&u32::MAX.to_le_bytes());
        r.extend_from_slice(&1u32.to_le_bytes());
        assert!(decode_batch_outputs(&r).is_err());
    }

    #[test]
    fn outputs_payload_roundtrip() {
        let out = vec![0.1f32; 10];
        assert_eq!(decode_outputs(&encode_outputs(&out)).unwrap(), out);
        let rows = vec![vec![1.0f32, 2.0], vec![3.0, 4.0]];
        assert_eq!(decode_batch_outputs(&encode_batch_outputs(&rows)).unwrap(), rows);
    }

    #[test]
    fn swap_payload_roundtrip_both_versions() {
        let (slot, src) = decode_swap(&encode_swap("mnist", "mnist-v2").unwrap(), 2).unwrap();
        assert_eq!((slot.as_str(), src.as_str()), ("mnist", "mnist-v2"));
        // v1 single-string form: targets the default slot.
        let (slot, src) = decode_swap(&encode_str("retrained"), 1).unwrap();
        assert_eq!((slot.as_str(), src.as_str()), ("", "retrained"));
        assert!(decode_str(&[5, 0, 0, 0, b'a']).is_err()); // declared 5, got 1
    }

    #[test]
    fn model_list_roundtrip() {
        let models = vec![
            ModelInfo {
                slot: "mnist".into(),
                model: "mnist".into(),
                version: 3,
                input_dim: 784,
                output_dim: 10,
                generation: 7,
                precision: Precision::Spx,
            },
            ModelInfo {
                slot: "qnet".into(),
                model: "qnet-retrained".into(),
                version: 1,
                input_dim: 6,
                output_dim: 3,
                generation: 1,
                precision: Precision::Int4,
            },
        ];
        let payload = encode_model_list(&models).unwrap();
        assert_eq!(decode_model_list(&payload).unwrap(), models);
        // Hostile count rejected before allocation.
        let mut p = Vec::new();
        p.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_model_list(&p).is_err());
    }

    #[test]
    fn precision_byte_contract() {
        for (p, byte, label) in [
            (Precision::F32, 0u8, "f32"),
            (Precision::Spx, 1, "spx"),
            (Precision::Int8, 2, "int8"),
            (Precision::Int4, 3, "int4"),
        ] {
            assert_eq!(p.as_u8(), byte);
            assert_eq!(Precision::from_u8(byte), Some(p));
            assert_eq!(p.label(), label);
            assert_eq!(Precision::parse(label), Some(p));
            assert_eq!(p.to_string(), label);
        }
        assert_eq!(Precision::from_u8(4), None);
        assert_eq!(Precision::from_u8(255), None);
        assert_eq!(Precision::parse("int2"), None);
        assert_eq!(Precision::parse(" int8 "), Some(Precision::Int8));
    }

    #[test]
    fn model_list_precision_suffix_is_version_gated() {
        let models = vec![
            ModelInfo {
                slot: "a".into(),
                model: "a".into(),
                version: 1,
                input_dim: 8,
                output_dim: 3,
                generation: 2,
                precision: Precision::Int8,
            },
            ModelInfo {
                slot: "b".into(),
                model: "b-v2".into(),
                version: 2,
                input_dim: 8,
                output_dim: 3,
                generation: 5,
                precision: Precision::Int4,
            },
        ];
        // Pre-v4 framing omits the suffix; decoding reports the f32
        // default.
        let v3 = encode_model_list_at(&models, 3).unwrap();
        let back = decode_model_list(&v3).unwrap();
        assert!(back.iter().all(|m| m.precision == Precision::F32));
        assert_eq!(back[0].slot, "a");
        // v4 framing is a strict extension: its prefix is byte-identical
        // to the v3 payload, with one precision byte per entry after.
        let v4 = encode_model_list_at(&models, 4).unwrap();
        assert_eq!(&v4[..v3.len()], &v3[..]);
        assert_eq!(v4.len(), v3.len() + models.len());
        assert_eq!(decode_model_list(&v4).unwrap(), models);
        // Unknown precision byte rejected by name.
        let mut bad = v4.clone();
        *bad.last_mut().unwrap() = 9;
        let err = decode_model_list(&bad).unwrap_err();
        assert!(err.contains("precision"), "{err}");
        // A partial suffix (one byte for two models) is malformed.
        let mut partial = v4.clone();
        partial.pop();
        assert!(decode_model_list(&partial).is_err());
    }

    #[test]
    fn swap_precision_suffix_roundtrip_and_rejection() {
        // With a precision byte, v4 decoding surfaces it.
        let p = encode_swap_precision("mnist", "mnist-v2", Some(Precision::Int4)).unwrap();
        let (slot, src, prec) = decode_swap_precision(&p, 4).unwrap();
        assert_eq!((slot.as_str(), src.as_str()), ("mnist", "mnist-v2"));
        assert_eq!(prec, Some(Precision::Int4));
        // The plain decoder still accepts the payload (and discards it).
        assert_eq!(decode_swap(&p, 4).unwrap().1, "mnist-v2");
        // Without the byte, the payload is exactly the v2 layout.
        let bare = encode_swap_precision("mnist", "mnist-v2", None).unwrap();
        assert_eq!(bare, encode_swap("mnist", "mnist-v2").unwrap());
        assert_eq!(decode_swap_precision(&bare, 4).unwrap().2, None);
        // A trailing byte on pre-v4 framing is trailing garbage, not a
        // precision — BadRequest territory, never a panic.
        assert!(decode_swap_precision(&p, 2).is_err());
        assert!(decode_swap_precision(&p, 3).is_err());
        // An unknown precision value is rejected by name at v4.
        let mut bad = bare.clone();
        bad.push(9);
        let err = decode_swap_precision(&bad, 4).unwrap_err();
        assert!(err.contains("precision"), "{err}");
    }

    #[test]
    fn stop_flag_interrupts_timeout_reads() {
        // A reader that always reports WouldBlock simulates a socket
        // read-timeout tick; with the flag raised the read must stop.
        struct AlwaysTimeout;
        impl std::io::Read for AlwaysTimeout {
            fn read(&mut self, _buf: &mut [u8]) -> std::io::Result<usize> {
                Err(std::io::Error::from(ErrorKind::WouldBlock))
            }
        }
        let stop = AtomicBool::new(true);
        assert!(matches!(
            read_frame_with(&mut AlwaysTimeout, 1024, Some(&stop)),
            Err(ReadError::Stopped)
        ));
        // Without a stop flag a timeout is a plain IO error.
        assert!(matches!(
            read_frame(&mut AlwaysTimeout, 1024),
            Err(ReadError::Io(_))
        ));
    }

    #[test]
    fn read_deadline_trips_on_stalled_reader() {
        // A reader that yields one byte then stalls forever simulates a
        // slowloris client mid-frame.
        struct Dribble {
            sent: bool,
        }
        impl std::io::Read for Dribble {
            fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
                if self.sent {
                    Err(std::io::Error::from(ErrorKind::WouldBlock))
                } else {
                    self.sent = true;
                    buf[0] = b'E';
                    Ok(1)
                }
            }
        }
        // Deadline already in the past: first WouldBlock tick trips it.
        let past = Instant::now() - std::time::Duration::from_millis(1);
        assert!(matches!(
            read_frame_deadline(&mut Dribble { sent: false }, 1024, None, Some(past)),
            Err(ReadError::TimedOut)
        ));
        // A raised stop flag still wins over the deadline.
        let stop = AtomicBool::new(true);
        assert!(matches!(
            read_frame_deadline(&mut Dribble { sent: false }, 1024, Some(&stop), Some(past)),
            Err(ReadError::Stopped)
        ));
        // With a generous deadline a complete frame still reads fine.
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::ok(Opcode::Ping, 7, b"hi".to_vec())).unwrap();
        let far = Instant::now() + std::time::Duration::from_secs(60);
        let frame =
            read_frame_deadline(&mut Cursor::new(buf), 1024, None, Some(far)).unwrap();
        assert_eq!(frame.request_id, 7);
    }

    #[test]
    fn health_payload_roundtrip() {
        let report = HealthReport {
            degraded: true,
            degraded_transitions: 3,
            read_timeouts: 2,
            pools: vec![
                PoolHealth {
                    name: "cpu/default".into(),
                    queue_depth: 17,
                    queue_capacity: 1024,
                    replicas: 2,
                    shed: 40,
                    expired: 9,
                },
                PoolHealth {
                    name: "fpga/default".into(),
                    queue_depth: 0,
                    queue_capacity: 1024,
                    replicas: 1,
                    shed: 0,
                    expired: 0,
                },
            ],
            busy_rejected: 5,
            bad_requests: vec![("magic".into(), 2), ("version".into(), 1)],
        };
        let payload = encode_health(&report).unwrap();
        assert_eq!(decode_health(&payload).unwrap(), report);
        // Hostile pool count rejected before allocation.
        let mut p = vec![0u8];
        p.extend_from_slice(&0u64.to_le_bytes());
        p.extend_from_slice(&0u64.to_le_bytes());
        p.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_health(&p).is_err());
        // Bad degraded flag rejected.
        let mut p = encode_health(&report).unwrap();
        p[0] = 7;
        assert!(decode_health(&p).is_err());
        // Truncating the v3 base layout is always an error, not a
        // panic. (Truncating a v4 payload exactly at the extension
        // boundary yields a valid v3 payload by design — that case is
        // pinned in `health_v4_extension_is_version_gated`.)
        let base = encode_health_at(&report, 3).unwrap();
        for cut in 0..base.len() {
            assert!(decode_health(&base[..cut]).is_err(), "cut at {cut}");
        }
        // Truncating *inside* the extension block is also an error.
        let full = encode_health(&report).unwrap();
        for cut in base.len() + 1..full.len() {
            assert!(decode_health(&full[..cut]).is_err(), "cut at {cut}");
        }
    }

    #[test]
    fn health_v4_extension_is_version_gated() {
        let report = HealthReport {
            degraded: false,
            degraded_transitions: 1,
            read_timeouts: 0,
            pools: vec![PoolHealth {
                name: "cpu/default".into(),
                queue_depth: 1,
                queue_capacity: 64,
                replicas: 1,
                shed: 0,
                expired: 0,
            }],
            busy_rejected: 9,
            bad_requests: vec![("opcode".into(), 4)],
        };
        // Pre-v4 framing omits the extension entirely; decoding it
        // reports zeroed extension fields.
        let v3 = encode_health_at(&report, 3).unwrap();
        let back = decode_health(&v3).unwrap();
        assert_eq!(back.busy_rejected, 0);
        assert!(back.bad_requests.is_empty());
        assert_eq!(back.pools, report.pools);
        // v4 framing carries it, and the v4 payload is a strict
        // extension: its prefix is byte-identical to the v3 payload.
        let v4 = encode_health_at(&report, 4).unwrap();
        assert_eq!(&v4[..v3.len()], &v3[..]);
        assert_eq!(decode_health(&v4).unwrap(), report);
        // Hostile cause count rejected before allocation.
        let mut p = v3.clone();
        p.extend_from_slice(&0u64.to_le_bytes());
        p.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_health(&p).is_err());
    }

    #[test]
    fn observability_opcodes_round_trip_at_v4() {
        for (op, byte) in [(Opcode::DumpTrace, 7u8), (Opcode::StatsV2, 8u8)] {
            assert_eq!(op as u8, byte);
            assert_eq!(Opcode::from_u8(byte), Some(op));
            let f = Frame::ok(op, 11, b"{}".to_vec());
            assert_eq!(f.version, 4, "Frame::ok must stamp the current version");
            assert_eq!(roundtrip(&f), f);
        }
        assert_eq!(Opcode::from_u8(9), None);
    }

    /// Pull frames off `bytes` with the blocking reader until EOF or a
    /// framing error, mirroring what a connection thread used to see.
    fn drain_blocking(bytes: &[u8]) -> (Vec<Frame>, Option<String>) {
        let mut cur = Cursor::new(bytes.to_vec());
        let mut frames = Vec::new();
        loop {
            match read_frame(&mut cur, DEFAULT_MAX_PAYLOAD) {
                Ok(f) => frames.push(f),
                Err(ReadError::Eof) => return (frames, None),
                Err(ReadError::Protocol(m)) => return (frames, Some(m)),
                Err(e) => panic!("unexpected read error {e:?}"),
            }
        }
    }

    /// Same stream through the incremental assembler, `chunk` bytes at
    /// a time, with the EOF-mid-frame rule the event loop applies.
    fn drain_incremental(bytes: &[u8], chunk: usize) -> (Vec<Frame>, Option<String>) {
        let mut asm = FrameAssembler::new();
        let mut frames = Vec::new();
        for c in bytes.chunks(chunk.max(1)) {
            asm.push(c);
            loop {
                match asm.next_frame(DEFAULT_MAX_PAYLOAD) {
                    Ok(Some(f)) => frames.push(f),
                    Ok(None) => break,
                    Err(m) => return (frames, Some(m)),
                }
            }
        }
        if asm.is_mid_frame() {
            (frames, Some(FrameAssembler::eof_mid_frame()))
        } else {
            (frames, None)
        }
    }

    #[test]
    fn frame_assembler_matches_blocking_reader_at_every_chunk_size() {
        let mut valid = Vec::new();
        write_frame(&mut valid, &Frame::ok(Opcode::Ping, 1, b"ping".to_vec())).unwrap();
        write_frame(
            &mut valid,
            &Frame::ok(Opcode::Infer, 2, encode_infer(BACKEND_ANY, "m", &[0.5; 16]).unwrap()),
        )
        .unwrap();
        write_frame(&mut valid, &Frame::ok(Opcode::Health, 3, Vec::new()).at_version(3)).unwrap();

        let mut bad_magic = vec![0xde; HEADER_LEN];
        let mut bad_version = valid[..HEADER_LEN].to_vec();
        bad_version[4] = 99;
        let mut bad_opcode = valid[..HEADER_LEN].to_vec();
        bad_opcode[6] = 0xff;
        let mut bad_status = valid[..HEADER_LEN].to_vec();
        bad_status[7] = 0xee;
        let mut oversized = valid[..HEADER_LEN].to_vec();
        oversized[16..20].copy_from_slice(&u32::MAX.to_le_bytes());
        let truncated_header = valid[..10].to_vec();
        let truncated_payload = valid[..HEADER_LEN + 2].to_vec();
        let mut valid_then_garbage = valid.clone();
        valid_then_garbage.extend_from_slice(&[0xde, 0xad, 0xbe, 0xef]);
        bad_magic.extend_from_slice(b"trailing");
        let streams: Vec<Vec<u8>> = vec![
            valid.clone(),
            bad_magic,
            bad_version,
            bad_opcode,
            bad_status,
            oversized,
            truncated_header,
            truncated_payload,
            valid_then_garbage,
            Vec::new(),
        ];
        for (i, stream) in streams.iter().enumerate() {
            let want = drain_blocking(stream);
            for chunk in [1, 2, 3, 7, stream.len().max(1)] {
                let got = drain_incremental(stream, chunk);
                assert_eq!(got, want, "stream {i} chunk {chunk}");
            }
        }
    }

    #[test]
    fn frame_assembler_validates_nothing_before_a_full_header() {
        // The blocking reader buffers the full 20-byte header before
        // any validation; the assembler must not report bad magic off
        // a prefix.
        let mut asm = FrameAssembler::new();
        asm.push(&[0xde, 0xad]);
        assert_eq!(asm.next_frame(DEFAULT_MAX_PAYLOAD), Ok(None));
        assert!(asm.is_mid_frame());
        asm.push(&vec![0u8; HEADER_LEN - 2]);
        let err = asm.next_frame(DEFAULT_MAX_PAYLOAD).unwrap_err();
        assert!(err.contains("bad magic"), "{err}");
    }

    #[test]
    fn health_loop_gauges_block_is_a_strict_suffix() {
        let report = HealthReport {
            degraded: false,
            degraded_transitions: 2,
            read_timeouts: 1,
            pools: vec![PoolHealth {
                name: "cpu/default".into(),
                queue_depth: 3,
                queue_capacity: 64,
                replicas: 1,
                shed: 1,
                expired: 0,
            }],
            busy_rejected: 4,
            bad_requests: vec![("magic".into(), 1)],
        };
        let gauges = LoopGauges {
            registered_conns: 11,
            ready_events: 222,
            poll_ticks: 333,
            pending_writeback_bytes: 44,
            timer_depth: 5,
        };
        // v4 payload with gauges is a strict byte extension of the
        // gauge-less v4 payload, which old decoders keep accepting.
        let v4 = encode_health_at(&report, 4).unwrap();
        let full = encode_health_loop(&report, &gauges, 4).unwrap();
        assert_eq!(&full[..v4.len()], &v4[..]);
        assert_eq!(decode_health_loop(&full).unwrap(), (report.clone(), Some(gauges)));
        assert_eq!(decode_health(&full).unwrap(), report);
        // Gauge-less payloads decode to None, pre-v4 framing omits the
        // block entirely.
        assert_eq!(decode_health_loop(&v4).unwrap(), (report.clone(), None));
        let v3 = encode_health_loop(&report, &gauges, 3).unwrap();
        assert_eq!(v3, encode_health_at(&report, 3).unwrap());
        // Truncating inside the gauge block is malformed, not a panic.
        for cut in v4.len() + 1..full.len() {
            assert!(decode_health_loop(&full[..cut]).is_err(), "cut at {cut}");
        }
    }

    #[test]
    fn health_autoscale_block_is_a_strict_suffix() {
        let report = HealthReport {
            degraded: true,
            degraded_transitions: 3,
            read_timeouts: 0,
            pools: vec![PoolHealth {
                name: "int4/default".into(),
                queue_depth: 1,
                queue_capacity: 64,
                replicas: 3,
                shed: 0,
                expired: 0,
            }],
            busy_rejected: 0,
            bad_requests: Vec::new(),
        };
        let gauges = LoopGauges { registered_conns: 7, ..LoopGauges::default() };
        let autoscale = AutoscaleHealth {
            enabled: true,
            min_replicas: 1,
            max_replicas: 4,
            scale_ups: 9,
            scale_downs: 6,
            power_mw: 3125,
            budget_mw: 1000,
            power_degraded: true,
        };
        // The autoscale block is a strict byte extension of the loop
        // payload; every older decoder keeps accepting the full frame.
        let with_loop = encode_health_loop(&report, &gauges, 4).unwrap();
        let full = encode_health_full(&report, &gauges, &autoscale, 4).unwrap();
        assert_eq!(&full[..with_loop.len()], &with_loop[..]);
        assert_eq!(
            decode_health_full(&full).unwrap(),
            (report.clone(), Some(gauges), Some(autoscale))
        );
        assert_eq!(decode_health_loop(&full).unwrap(), (report.clone(), Some(gauges)));
        assert_eq!(decode_health(&full).unwrap(), report);
        // Autoscale-less payloads decode to None; pre-v4 framing omits
        // every trailing block.
        assert_eq!(decode_health_full(&with_loop).unwrap().2, None);
        let v3 = encode_health_full(&report, &gauges, &autoscale, 3).unwrap();
        assert_eq!(v3, encode_health_at(&report, 3).unwrap());
        // A disabled autoscaler still round-trips (all-zero block).
        let off = encode_health_full(&report, &gauges, &AutoscaleHealth::default(), 4).unwrap();
        assert_eq!(decode_health_full(&off).unwrap().2, Some(AutoscaleHealth::default()));
        // Truncating inside the autoscale block is malformed, not a panic.
        for cut in with_loop.len() + 1..full.len() {
            assert!(decode_health_full(&full[..cut]).is_err(), "cut at {cut}");
        }
    }
}
