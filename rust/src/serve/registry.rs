//! Multi-model registry: a catalog of versioned EMLP blobs + SPx code
//! tensors, served through independently hot-swappable *slots*.
//!
//! Two levels of naming:
//!
//! * the **catalog** holds every registered [`ModelVersion`] by name
//!   (re-registering a name bumps its version) — the pool of swap
//!   candidates;
//! * **slots** ([`ModelSlot`]) are the names clients route by on the
//!   wire. Each slot points at one catalog version and carries its own
//!   monotonically increasing *generation* counter, so swapping one
//!   served model never disturbs another.
//!
//! The swappable backends below hold an `Arc<ModelSlot>` and check its
//! generation between batches: a batch already on a backend finishes on
//! the model it started with, the next batch picks up the newly
//! activated version — so `SwapModel` never drops in-flight requests.
//! Persistence reuses the EMLP blob format (`util::serde`): a model
//! file carries the fp32 tensors [`Mlp::to_tensors`] emits plus sidecar
//! tensors with the SPx level indices, per-tensor scales and per-layer
//! data ranges, so the quantized model reloads bit-identically without
//! re-running calibration.

use crate::coordinator::backend::{Backend, CpuBackend, FpgaBackend, VsqBackend};
use crate::coordinator::server::SharedBackendFactory;
use crate::fpga::accelerator::{AccelConfig, Accelerator, QuantizedLayer, QuantizedMlp};
use crate::fpga::stats::CycleStats;
use crate::nn::vsq::{VsqMlp, DEFAULT_GROUP_ROWS};
use crate::nn::Mlp;
use crate::quant::spx::{SpxConfig, SpxTensor};
use crate::quant::Calibration;
use crate::serve::wire::Precision;
use crate::util::serde::{load_tensors, save_tensors, NamedTensor};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex};

/// One immutable registered model: the fp32 network plus its SPx
/// quantization (what the FPGA-sim backend executes).
#[derive(Debug, Clone)]
pub struct ModelVersion {
    pub name: String,
    /// Monotonic per-name version, starting at 1.
    pub version: u32,
    pub mlp: Mlp,
    pub quantized: QuantizedMlp,
    /// Per-vector-scaled int8 artifact (see [`crate::quant::vsq`]).
    /// Derived deterministically from `mlp` at registration/load time —
    /// no blob format change needed, a reload requantizes to the exact
    /// same codes.
    pub vsq8: VsqMlp,
    /// Per-vector-scaled int4 artifact.
    pub vsq4: VsqMlp,
}

impl ModelVersion {
    fn build(name: &str, version: u32, mlp: Mlp, quantized: QuantizedMlp) -> Arc<ModelVersion> {
        let vsq8 = VsqMlp::from_mlp(&mlp, 8, DEFAULT_GROUP_ROWS, Calibration::MaxAbs, None);
        let vsq4 = VsqMlp::from_mlp(&mlp, 4, DEFAULT_GROUP_ROWS, Calibration::MaxAbs, None);
        Arc::new(ModelVersion { name: name.to_string(), version, mlp, quantized, vsq8, vsq4 })
    }

    pub fn input_dim(&self) -> usize {
        self.mlp.input_dim()
    }

    pub fn output_dim(&self) -> usize {
        self.mlp.output_dim()
    }

    /// Packed weight bytes one sample streams under `precision` — the
    /// lower-better `bytes_per_sample` number pools report in metrics.
    pub fn weight_bytes(&self, precision: Precision) -> u64 {
        match precision {
            Precision::F32 => crate::nn::vsq::f32_weight_bytes(&self.mlp),
            Precision::Spx => {
                // SPx codes (sign + term bits) packed, plus f32 biases.
                let bias: u64 =
                    self.mlp.layers.iter().map(|l| 4 * l.b.len() as u64).sum();
                self.quantized.weight_bits().div_ceil(8) + bias
            }
            Precision::Int8 => self.vsq8.weight_bytes(),
            Precision::Int4 => self.vsq4.weight_bytes(),
        }
    }
}

/// A serving slot: the unit of routing and of hot swap. Backends bound
/// to the slot poll [`ModelSlot::generation`] (one atomic load) between
/// batches and reload from [`ModelSlot::active`] when it moved.
pub struct ModelSlot {
    name: String,
    generation: AtomicU64,
    active: Mutex<Arc<ModelVersion>>,
    /// Preferred serving precision for `BACKEND_ANY` traffic on this
    /// slot, as a [`Precision`] wire byte; `NO_PREFERENCE` when unset.
    /// Set via `serve --precision` or a v4 `SwapModel` precision byte;
    /// read by routing and `ListModels`.
    preferred: AtomicU8,
}

/// Sentinel for [`ModelSlot::preferred`]: no precision preference.
const NO_PREFERENCE: u8 = u8::MAX;

impl ModelSlot {
    fn new(name: &str, model: Arc<ModelVersion>) -> Arc<ModelSlot> {
        Arc::new(ModelSlot {
            name: name.to_string(),
            generation: AtomicU64::new(1),
            active: Mutex::new(model),
            preferred: AtomicU8::new(NO_PREFERENCE),
        })
    }

    /// The slot's preferred serving precision, if one was selected.
    pub fn preferred_precision(&self) -> Option<Precision> {
        Precision::from_u8(self.preferred.load(Ordering::SeqCst))
    }

    /// Select (or clear) the slot's preferred serving precision.
    pub fn set_preferred_precision(&self, precision: Option<Precision>) {
        let byte = precision.map(|p| p.as_u8()).unwrap_or(NO_PREFERENCE);
        self.preferred.store(byte, Ordering::SeqCst);
    }

    /// The slot name clients route by.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// The model version currently served by this slot.
    pub fn active(&self) -> Arc<ModelVersion> {
        self.active.lock().unwrap().clone()
    }

    /// Swap generation (starts at 1, bumped per activation).
    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::SeqCst)
    }

    /// Install `model` and bump the generation. The store happens
    /// before the bump, so a backend that observes the new counter also
    /// observes the new active model.
    fn set_active(&self, model: Arc<ModelVersion>) -> u64 {
        *self.active.lock().unwrap() = model;
        self.generation.fetch_add(1, Ordering::SeqCst) + 1
    }
}

/// Why a swap was refused.
#[derive(Debug, PartialEq, Eq)]
pub enum SwapError {
    /// No catalog model registered under that name.
    UnknownModel(String),
    /// No serving slot with that name.
    UnknownSlot(String),
    /// The named model's I/O shape differs from the slot's active one —
    /// a swap would break requests already sized for the current
    /// signature.
    Incompatible(String),
}

impl std::fmt::Display for SwapError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SwapError::UnknownModel(name) => write!(f, "unknown model '{name}'"),
            SwapError::UnknownSlot(name) => write!(f, "unknown serving slot '{name}'"),
            SwapError::Incompatible(msg) => write!(f, "incompatible model: {msg}"),
        }
    }
}

impl std::error::Error for SwapError {}

struct RegistryInner {
    catalog: BTreeMap<String, Arc<ModelVersion>>,
    slots: BTreeMap<String, Arc<ModelSlot>>,
}

/// Thread-shared model store. See the module docs for the catalog/slot
/// split and the swap semantics.
pub struct ModelRegistry {
    spx: SpxConfig,
    /// Slot v1 clients (and the empty model name) route to.
    default_slot: String,
    inner: Mutex<RegistryInner>,
}

impl ModelRegistry {
    /// Create a registry with `mlp` registered under `name` (version 1)
    /// and serving in a slot of the same name — the default slot. `spx`
    /// is used to quantize every model registered through
    /// [`ModelRegistry::register_mlp`].
    pub fn new(name: &str, mlp: Mlp, spx: SpxConfig) -> Arc<ModelRegistry> {
        let quantized = QuantizedMlp::from_mlp(&mlp, &spx, Calibration::MaxAbs, None);
        let first = ModelVersion::build(name, 1, mlp, quantized);
        let mut catalog = BTreeMap::new();
        catalog.insert(name.to_string(), first.clone());
        let mut slots = BTreeMap::new();
        slots.insert(name.to_string(), ModelSlot::new(name, first));
        Arc::new(ModelRegistry {
            spx,
            default_slot: name.to_string(),
            inner: Mutex::new(RegistryInner { catalog, slots }),
        })
    }

    /// Register (or re-register, bumping the version) a model under
    /// `name` in the catalog without activating it anywhere.
    pub fn register_mlp(&self, name: &str, mlp: Mlp) -> Arc<ModelVersion> {
        let quantized = QuantizedMlp::from_mlp(&mlp, &self.spx, Calibration::MaxAbs, None);
        let mut inner = self.inner.lock().unwrap();
        let version = inner.catalog.get(name).map(|m| m.version + 1).unwrap_or(1);
        let model = ModelVersion::build(name, version, mlp, quantized);
        inner.catalog.insert(name.to_string(), model.clone());
        model
    }

    /// Start serving catalog model `name` in a slot of the same name
    /// (idempotent: an existing slot is returned untouched).
    pub fn add_slot(&self, name: &str) -> Result<Arc<ModelSlot>, SwapError> {
        let mut inner = self.inner.lock().unwrap();
        if let Some(slot) = inner.slots.get(name) {
            return Ok(slot.clone());
        }
        let model = inner
            .catalog
            .get(name)
            .cloned()
            .ok_or_else(|| SwapError::UnknownModel(name.to_string()))?;
        let slot = ModelSlot::new(name, model);
        inner.slots.insert(name.to_string(), slot.clone());
        Ok(slot)
    }

    /// Atomically activate catalog model `source` into serving slot
    /// `slot_name` (the empty string targets the default slot). Fails
    /// if either name is unknown or the I/O signatures differ. Returns
    /// the model and the slot's new generation.
    pub fn activate_into(
        &self,
        slot_name: &str,
        source: &str,
    ) -> Result<(Arc<ModelVersion>, u64), SwapError> {
        let slot_name =
            if slot_name.is_empty() { self.default_slot.as_str() } else { slot_name };
        let inner = self.inner.lock().unwrap();
        let slot = inner
            .slots
            .get(slot_name)
            .cloned()
            .ok_or_else(|| SwapError::UnknownSlot(slot_name.to_string()))?;
        let model = inner
            .catalog
            .get(source)
            .cloned()
            .ok_or_else(|| SwapError::UnknownModel(source.to_string()))?;
        let active = slot.active();
        if model.input_dim() != active.input_dim()
            || model.output_dim() != active.output_dim()
        {
            return Err(SwapError::Incompatible(format!(
                "'{source}' is {}→{}, slot '{slot_name}' serves '{}' at {}→{}",
                model.input_dim(),
                model.output_dim(),
                active.name,
                active.input_dim(),
                active.output_dim()
            )));
        }
        // set_active bumps the generation while we hold the registry
        // lock, so concurrent activations into one slot serialize.
        let generation = slot.set_active(model.clone());
        Ok((model, generation))
    }

    /// v1 semantics: activate catalog model `name` into the default
    /// slot.
    pub fn activate(&self, name: &str) -> Result<(Arc<ModelVersion>, u64), SwapError> {
        self.activate_into("", name)
    }

    /// The default slot's active model (v1 view).
    pub fn active(&self) -> Arc<ModelVersion> {
        self.default_slot().active()
    }

    /// The default slot's generation (v1 view).
    pub fn generation(&self) -> u64 {
        self.default_slot().generation()
    }

    /// The slot v1 clients and the empty model name route to.
    pub fn default_slot(&self) -> Arc<ModelSlot> {
        self.inner.lock().unwrap().slots[&self.default_slot].clone()
    }

    pub fn default_slot_name(&self) -> &str {
        &self.default_slot
    }

    /// Look up a serving slot; the empty name resolves to the default
    /// slot.
    pub fn slot(&self, name: &str) -> Option<Arc<ModelSlot>> {
        let name = if name.is_empty() { self.default_slot.as_str() } else { name };
        self.inner.lock().unwrap().slots.get(name).cloned()
    }

    /// Every serving slot, default first, the rest in name order —
    /// the order engine pools are built in.
    pub fn slots(&self) -> Vec<Arc<ModelSlot>> {
        let inner = self.inner.lock().unwrap();
        let mut out = vec![inner.slots[&self.default_slot].clone()];
        out.extend(
            inner.slots.iter().filter(|(n, _)| **n != self.default_slot).map(|(_, s)| s.clone()),
        );
        out
    }

    /// Registered catalog model names.
    pub fn names(&self) -> Vec<String> {
        self.inner.lock().unwrap().catalog.keys().cloned().collect()
    }

    /// Look up a registered catalog model without activating it.
    pub fn get(&self, name: &str) -> Option<Arc<ModelVersion>> {
        self.inner.lock().unwrap().catalog.get(name).cloned()
    }

    /// Persist `name`'s latest version: the fp32 tensors plus SPx
    /// sidecar tensors (level indices, scales, data ranges, term bits).
    pub fn save_blob(&self, name: &str, path: &Path) -> Result<()> {
        let model = self.get(name).with_context(|| format!("unknown model '{name}'"))?;
        let mut tensors = model.mlp.to_tensors();
        tensors.push(NamedTensor::new(
            "spx_term_bits",
            vec![model.quantized.layers[0].w.config.num_terms()],
            model.quantized.layers[0]
                .w
                .config
                .term_bits
                .iter()
                .map(|&b| b as f32)
                .collect(),
        ));
        for (i, layer) in model.quantized.layers.iter().enumerate() {
            tensors.push(NamedTensor::new(
                format!("spx_idx{i}"),
                layer.w.shape.clone(),
                layer.w.indices.iter().map(|&ix| ix as f32).collect(),
            ));
            tensors.push(NamedTensor::new(format!("spx_scale{i}"), vec![1], vec![layer.w.scale]));
            tensors.push(NamedTensor::new(
                format!("spx_dscale{i}"),
                vec![1],
                vec![layer.d_scale],
            ));
        }
        save_tensors(path, &tensors)
    }

    /// Load a blob written by [`ModelRegistry::save_blob`] (or a plain
    /// `Mlp::save` checkpoint, which is then quantized with the
    /// registry's SPx config) and register it in the catalog under
    /// `name`.
    pub fn load_blob(&self, name: &str, path: &Path) -> Result<Arc<ModelVersion>> {
        let tensors =
            load_tensors(path).with_context(|| format!("load model blob {}", path.display()))?;
        let mlp = Mlp::from_tensors(&tensors)?;
        let find = |tag: &str| tensors.iter().find(|t| t.name == tag);
        let quantized = match find("spx_term_bits") {
            None => QuantizedMlp::from_mlp(&mlp, &self.spx, Calibration::MaxAbs, None),
            Some(bits) => {
                // Validate before SpxConfig::new / SpxCodebook::build /
                // PackedCodes, whose asserts would panic on a corrupt
                // blob (the packed layout supports at most 4 terms).
                let term_bits: Vec<u32> = bits.data.iter().map(|&b| b as u32).collect();
                if term_bits.is_empty()
                    || term_bits.len() > 4
                    || term_bits.iter().any(|&b| !(1..=7).contains(&b))
                {
                    bail!("spx_term_bits {:?} out of range", bits.data);
                }
                let config = SpxConfig::new(term_bits);
                let mut layers = Vec::with_capacity(mlp.layers.len());
                for (i, layer) in mlp.layers.iter().enumerate() {
                    let idx = find(&format!("spx_idx{i}"))
                        .with_context(|| format!("blob missing spx_idx{i}"))?;
                    let scale = find(&format!("spx_scale{i}"))
                        .with_context(|| format!("blob missing spx_scale{i}"))?;
                    let d_scale = find(&format!("spx_dscale{i}"))
                        .with_context(|| format!("blob missing spx_dscale{i}"))?;
                    let indices: Vec<u16> = idx
                        .data
                        .iter()
                        .map(|&v| {
                            if v < 0.0 || v.fract() != 0.0 || v > u16::MAX as f32 {
                                bail!("spx_idx{i}: bad level index {v}")
                            } else {
                                Ok(v as u16)
                            }
                        })
                        .collect::<Result<_>>()?;
                    let scale_val = scale
                        .data
                        .first()
                        .copied()
                        .with_context(|| format!("spx_scale{i} is empty"))?;
                    let d_scale_val = d_scale
                        .data
                        .first()
                        .copied()
                        .with_context(|| format!("spx_dscale{i} is empty"))?;
                    let w = SpxTensor::from_parts(&config, &idx.shape, indices, scale_val)
                        .map_err(|e| anyhow::anyhow!("spx_idx{i}: {e}"))?;
                    if w.shape != vec![layer.w.rows, layer.w.cols] {
                        bail!(
                            "spx_idx{i} shape {:?} vs weight {}x{}",
                            w.shape,
                            layer.w.rows,
                            layer.w.cols
                        );
                    }
                    layers.push(QuantizedLayer {
                        w,
                        b: layer.b.clone(),
                        activation: layer.activation,
                        d_scale: d_scale_val,
                    });
                }
                QuantizedMlp { layers }
            }
        };
        let mut inner = self.inner.lock().unwrap();
        let version = inner.catalog.get(name).map(|m| m.version + 1).unwrap_or(1);
        let model = ModelVersion::build(name, version, mlp, quantized);
        inner.catalog.insert(name.to_string(), model.clone());
        Ok(model)
    }
}

// ---------------------------------------------------------------------------
// Swappable backends: coordinator backends bound to one serving slot,
// refreshing themselves from it between batches.
// ---------------------------------------------------------------------------

/// CPU backend following a slot's active model.
pub struct SwappableCpuBackend {
    slot: Arc<ModelSlot>,
    seen: u64,
    inner: CpuBackend,
}

impl SwappableCpuBackend {
    pub fn new(slot: Arc<ModelSlot>) -> Self {
        let seen = slot.generation();
        let inner = CpuBackend::new(slot.active().mlp.clone());
        SwappableCpuBackend { slot, seen, inner }
    }

    fn refresh(&mut self) {
        let generation = self.slot.generation();
        if generation != self.seen {
            self.inner = CpuBackend::new(self.slot.active().mlp.clone());
            self.seen = generation;
        }
    }
}

impl Backend for SwappableCpuBackend {
    fn name(&self) -> &str {
        self.inner.name()
    }

    fn max_batch(&self) -> usize {
        self.inner.max_batch()
    }

    fn infer(&mut self, inputs: &[Vec<f32>]) -> Result<(Vec<Vec<f32>>, Option<CycleStats>)> {
        self.refresh();
        self.inner.infer(inputs)
    }

    fn calibration_input(&self) -> Option<Vec<f32>> {
        self.inner.calibration_input()
    }
}

/// FPGA-simulator backend following a slot's active model: a swap
/// rebuilds the [`Accelerator`] (decoded-weight caches and all) from
/// the new version's SPx tensors.
pub struct SwappableFpgaBackend {
    slot: Arc<ModelSlot>,
    config: AccelConfig,
    seen: u64,
    inner: FpgaBackend,
}

impl SwappableFpgaBackend {
    pub fn new(slot: Arc<ModelSlot>, config: AccelConfig) -> Self {
        let seen = slot.generation();
        let accel = Accelerator::new(slot.active().quantized.clone(), config);
        SwappableFpgaBackend { slot, config, seen, inner: FpgaBackend::new(accel) }
    }

    fn refresh(&mut self) {
        let generation = self.slot.generation();
        if generation != self.seen {
            let accel = Accelerator::new(self.slot.active().quantized.clone(), self.config);
            self.inner = FpgaBackend::new(accel);
            self.seen = generation;
        }
    }
}

impl Backend for SwappableFpgaBackend {
    fn name(&self) -> &str {
        self.inner.name()
    }

    fn max_batch(&self) -> usize {
        self.inner.max_batch()
    }

    fn infer(&mut self, inputs: &[Vec<f32>]) -> Result<(Vec<Vec<f32>>, Option<CycleStats>)> {
        self.refresh();
        self.inner.infer(inputs)
    }

    fn calibration_input(&self) -> Option<Vec<f32>> {
        self.inner.calibration_input()
    }
}

/// Low-bit integer backend following a slot's active model: a swap
/// rebuilds the [`VsqBackend`] from the new version's pre-quantized
/// int8/int4 artifact (no requantization on the serving path).
pub struct SwappableVsqBackend {
    slot: Arc<ModelSlot>,
    bits: u8,
    seen: u64,
    inner: VsqBackend,
}

impl SwappableVsqBackend {
    pub fn new(slot: Arc<ModelSlot>, bits: u8) -> Self {
        let seen = slot.generation();
        let inner = VsqBackend::new(Self::artifact(&slot, bits));
        SwappableVsqBackend { slot, bits, seen, inner }
    }

    fn artifact(slot: &ModelSlot, bits: u8) -> VsqMlp {
        let active = slot.active();
        match bits {
            4 => active.vsq4.clone(),
            _ => active.vsq8.clone(),
        }
    }

    fn refresh(&mut self) {
        let generation = self.slot.generation();
        if generation != self.seen {
            self.inner = VsqBackend::new(Self::artifact(&self.slot, self.bits));
            self.seen = generation;
        }
    }
}

impl Backend for SwappableVsqBackend {
    fn name(&self) -> &str {
        self.inner.name()
    }

    fn max_batch(&self) -> usize {
        self.inner.max_batch()
    }

    fn infer(&mut self, inputs: &[Vec<f32>]) -> Result<(Vec<Vec<f32>>, Option<CycleStats>)> {
        self.refresh();
        self.inner.infer(inputs)
    }

    fn calibration_input(&self) -> Option<Vec<f32>> {
        self.inner.calibration_input()
    }
}

/// Replicable coordinator factory for slot-following CPU workers.
pub fn swappable_cpu_factory(slot: Arc<ModelSlot>) -> SharedBackendFactory {
    Arc::new(move || Ok(Box::new(SwappableCpuBackend::new(slot.clone())) as Box<dyn Backend>))
}

/// Replicable coordinator factory for slot-following FPGA-sim workers.
pub fn swappable_fpga_factory(
    slot: Arc<ModelSlot>,
    config: AccelConfig,
) -> SharedBackendFactory {
    Arc::new(move || {
        Ok(Box::new(SwappableFpgaBackend::new(slot.clone(), config)) as Box<dyn Backend>)
    })
}

/// Replicable coordinator factory for slot-following int8/int4 workers.
pub fn swappable_vsq_factory(slot: Arc<ModelSlot>, bits: u8) -> SharedBackendFactory {
    Arc::new(move || {
        Ok(Box::new(SwappableVsqBackend::new(slot.clone(), bits)) as Box<dyn Backend>)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::activations::Activation;
    use crate::nn::mlp::MlpConfig;
    use crate::util::rng::Pcg32;
    use std::path::PathBuf;

    fn small_mlp(seed: u64) -> Mlp {
        let mut rng = Pcg32::new(seed);
        Mlp::new(
            MlpConfig {
                sizes: vec![8, 6, 3],
                activations: vec![Activation::Sigmoid, Activation::Sigmoid],
            },
            &mut rng,
        )
    }

    fn registry() -> Arc<ModelRegistry> {
        ModelRegistry::new("default", small_mlp(1), SpxConfig::sp2(5))
    }

    struct TestFile(PathBuf);

    impl TestFile {
        fn new(tag: &str) -> TestFile {
            TestFile(std::env::temp_dir().join(format!(
                "edgemlp_model_{tag}_{}_{}.emlp",
                std::process::id(),
                std::time::SystemTime::now()
                    .duration_since(std::time::UNIX_EPOCH)
                    .unwrap()
                    .subsec_nanos()
            )))
        }
    }

    impl Drop for TestFile {
        fn drop(&mut self) {
            let _ = std::fs::remove_file(&self.0);
        }
    }

    #[test]
    fn register_and_activate_bumps_generation() {
        let reg = registry();
        assert_eq!(reg.generation(), 1);
        assert_eq!(reg.active().version, 1);
        let v = reg.register_mlp("retrained", small_mlp(2));
        assert_eq!(v.version, 1);
        // Re-register under the same name bumps the version.
        assert_eq!(reg.register_mlp("retrained", small_mlp(3)).version, 2);
        let (model, generation) = reg.activate("retrained").unwrap();
        assert_eq!(model.version, 2);
        assert_eq!(generation, 2);
        assert_eq!(reg.active().name, "retrained");
        assert_eq!(reg.names(), vec!["default".to_string(), "retrained".to_string()]);
    }

    #[test]
    fn activate_unknown_and_incompatible_rejected() {
        let reg = registry();
        assert!(matches!(
            reg.activate("nope"),
            Err(SwapError::UnknownModel(name)) if name == "nope"
        ));
        let mut rng = Pcg32::new(9);
        let wide = Mlp::new(
            MlpConfig { sizes: vec![16, 4, 3], activations: vec![Activation::Sigmoid; 2] },
            &mut rng,
        );
        reg.register_mlp("wide", wide);
        assert!(matches!(reg.activate("wide"), Err(SwapError::Incompatible(_))));
        // A refused swap leaves the active model and generation alone.
        assert_eq!(reg.active().name, "default");
        assert_eq!(reg.generation(), 1);
    }

    #[test]
    fn slots_swap_independently() {
        let reg = registry();
        reg.register_mlp("qnet", small_mlp(2));
        reg.register_mlp("qnet-v2", small_mlp(3));
        let qnet = reg.add_slot("qnet").unwrap();
        assert_eq!(qnet.name(), "qnet");
        assert_eq!(qnet.generation(), 1);
        assert_eq!(reg.slots().len(), 2);
        // add_slot is idempotent.
        assert!(Arc::ptr_eq(&reg.add_slot("qnet").unwrap(), &qnet));

        // Swapping qnet's slot moves its generation, not the default's.
        let (model, generation) = reg.activate_into("qnet", "qnet-v2").unwrap();
        assert_eq!(model.name, "qnet-v2");
        assert_eq!(generation, 2);
        assert_eq!(qnet.generation(), 2);
        assert_eq!(qnet.active().name, "qnet-v2");
        assert_eq!(reg.generation(), 1, "default slot generation moved");
        assert_eq!(reg.active().name, "default");

        // Unknown slot is its own error.
        assert!(matches!(
            reg.activate_into("nope", "qnet"),
            Err(SwapError::UnknownSlot(name)) if name == "nope"
        ));
        // Slot for a model that is not in the catalog.
        assert!(matches!(reg.add_slot("missing"), Err(SwapError::UnknownModel(_))));
        // Empty slot name routes to the default slot.
        assert!(Arc::ptr_eq(&reg.slot("").unwrap(), &reg.default_slot()));
        assert_eq!(reg.default_slot_name(), "default");
    }

    #[test]
    fn slots_list_default_first() {
        let reg = registry();
        reg.register_mlp("alpha", small_mlp(2));
        reg.add_slot("alpha").unwrap();
        let names: Vec<String> =
            reg.slots().iter().map(|s| s.name().to_string()).collect();
        assert_eq!(names, vec!["default".to_string(), "alpha".to_string()]);
    }

    #[test]
    fn blob_roundtrip_preserves_quantized_model_bitwise() {
        let reg = registry();
        let file = TestFile::new("roundtrip");
        reg.save_blob("default", &file.0).unwrap();
        let back = reg.load_blob("reloaded", &file.0).unwrap();
        let orig = reg.get("default").unwrap();
        for (a, b) in back.quantized.layers.iter().zip(&orig.quantized.layers) {
            assert_eq!(a.w.decode(), b.w.decode());
            assert_eq!(a.w.indices, b.w.indices);
            assert_eq!(a.d_scale, b.d_scale);
            assert_eq!(a.b, b.b);
        }
        assert_eq!(back.mlp.layers[0].w.data, orig.mlp.layers[0].w.data);
    }

    #[test]
    fn plain_checkpoint_loads_and_requantizes() {
        let reg = registry();
        let file = TestFile::new("plain");
        small_mlp(4).save(&file.0).unwrap();
        let model = reg.load_blob("ckpt", &file.0).unwrap();
        assert_eq!(model.quantized.layers.len(), 2);
        assert_eq!(model.input_dim(), 8);
    }

    #[test]
    fn swappable_backends_follow_slot_activation() {
        let reg = registry();
        let v2 = small_mlp(2);
        reg.register_mlp("v2", v2.clone());
        let x = vec![0.4f32; 8];
        let slot = reg.default_slot();

        let mut cpu = SwappableCpuBackend::new(slot.clone());
        let (before, _) = cpu.infer(&[x.clone()]).unwrap();
        assert_eq!(before[0], reg.get("default").unwrap().mlp.forward_one(&x));

        let mut fpga = SwappableFpgaBackend::new(slot.clone(), AccelConfig::default_fpga());
        let (fpga_before, _) = fpga.infer(&[x.clone()]).unwrap();

        reg.activate("v2").unwrap();
        let (after, _) = cpu.infer(&[x.clone()]).unwrap();
        assert_eq!(after[0], v2.forward_one(&x));
        assert_ne!(before[0], after[0], "swap did not change cpu outputs");

        let (fpga_after, _) = fpga.infer(&[x.clone()]).unwrap();
        assert_ne!(fpga_before[0], fpga_after[0], "swap did not change fpga outputs");
    }

    #[test]
    fn vsq_artifacts_reload_bitwise_from_blob() {
        // No VSQ sidecar exists in the blob format: the artifact is
        // derived deterministically from the fp32 tensors, so a reload
        // must reproduce the exact codes and scales.
        let reg = registry();
        let file = TestFile::new("vsq");
        reg.save_blob("default", &file.0).unwrap();
        let back = reg.load_blob("reloaded", &file.0).unwrap();
        let orig = reg.get("default").unwrap();
        for (a, b) in back.vsq8.layers.iter().zip(&orig.vsq8.layers) {
            assert_eq!(a.w, b.w);
            assert_eq!(a.d_scale, b.d_scale);
        }
        for (a, b) in back.vsq4.layers.iter().zip(&orig.vsq4.layers) {
            assert_eq!(a.w, b.w);
        }
        assert_eq!(back.vsq8.bits(), 8);
        assert_eq!(back.vsq4.bits(), 4);
    }

    #[test]
    fn weight_bytes_order_across_precisions() {
        let reg = registry();
        let m = reg.active();
        let f32b = m.weight_bytes(Precision::F32);
        let spx = m.weight_bytes(Precision::Spx);
        let i8b = m.weight_bytes(Precision::Int8);
        let i4b = m.weight_bytes(Precision::Int4);
        assert!(i4b < i8b, "int4 {i4b} !< int8 {i8b}");
        assert!(i8b < f32b, "int8 {i8b} !< f32 {f32b}");
        assert!(spx < f32b, "spx {spx} !< f32 {f32b}");
    }

    #[test]
    fn slot_precision_preference_roundtrips() {
        let reg = registry();
        let slot = reg.default_slot();
        assert_eq!(slot.preferred_precision(), None);
        slot.set_preferred_precision(Some(Precision::Int4));
        assert_eq!(slot.preferred_precision(), Some(Precision::Int4));
        slot.set_preferred_precision(None);
        assert_eq!(slot.preferred_precision(), None);
    }

    #[test]
    fn swappable_vsq_backend_follows_slot_activation() {
        let reg = registry();
        let v2 = small_mlp(2);
        reg.register_mlp("v2", v2.clone());
        let x = vec![0.4f32; 8];
        let slot = reg.default_slot();
        for bits in [8u8, 4] {
            let mut be = SwappableVsqBackend::new(slot.clone(), bits);
            assert_eq!(be.name(), format!("int{bits}"));
            let (before, _) = be.infer(&[x.clone()]).unwrap();
            reg.activate("v2").unwrap();
            let (after, _) = be.infer(&[x.clone()]).unwrap();
            assert_ne!(before[0], after[0], "int{bits} swap did not change outputs");
            reg.activate("default").unwrap();
        }
    }

    #[test]
    fn backend_on_one_slot_ignores_other_slots_swaps() {
        let reg = registry();
        reg.register_mlp("other", small_mlp(2));
        reg.register_mlp("other-v2", small_mlp(3));
        reg.add_slot("other").unwrap();
        let x = vec![0.4f32; 8];
        let mut cpu = SwappableCpuBackend::new(reg.default_slot());
        let (before, _) = cpu.infer(&[x.clone()]).unwrap();
        reg.activate_into("other", "other-v2").unwrap();
        let (after, _) = cpu.infer(&[x.clone()]).unwrap();
        assert_eq!(before[0], after[0], "default-slot backend reacted to another slot");
    }
}
