//! Hot-swappable model registry: versioned EMLP blobs + SPx code
//! tensors, atomically activated into running backends.
//!
//! The registry holds every registered [`ModelVersion`] behind `Arc`s
//! and tracks the active one plus a monotonically increasing
//! *generation* counter. The swappable backends below check the
//! generation between batches: a batch that is already on a backend
//! finishes on the model it started with, the next batch picks up the
//! newly activated version — so `SwapModel` never drops in-flight
//! requests. Persistence reuses the EMLP blob format (`util::serde`):
//! a model file carries the fp32 tensors [`Mlp::to_tensors`] emits plus
//! sidecar tensors with the SPx level indices, per-tensor scales and
//! per-layer data ranges, so the quantized model reloads bit-identically
//! without re-running calibration.

use crate::coordinator::backend::{Backend, CpuBackend, FpgaBackend};
use crate::coordinator::server::BackendFactory;
use crate::fpga::accelerator::{AccelConfig, Accelerator, QuantizedLayer, QuantizedMlp};
use crate::fpga::stats::CycleStats;
use crate::nn::Mlp;
use crate::quant::spx::{SpxConfig, SpxTensor};
use crate::quant::Calibration;
use crate::util::serde::{load_tensors, save_tensors, NamedTensor};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// One immutable registered model: the fp32 network plus its SPx
/// quantization (what the FPGA-sim backend executes).
#[derive(Debug, Clone)]
pub struct ModelVersion {
    pub name: String,
    /// Monotonic per-name version, starting at 1.
    pub version: u32,
    pub mlp: Mlp,
    pub quantized: QuantizedMlp,
}

impl ModelVersion {
    pub fn input_dim(&self) -> usize {
        self.mlp.input_dim()
    }

    pub fn output_dim(&self) -> usize {
        self.mlp.output_dim()
    }
}

/// Why a swap was refused.
#[derive(Debug, PartialEq, Eq)]
pub enum SwapError {
    /// No model registered under that name.
    UnknownModel(String),
    /// The named model's I/O shape differs from the active one — a swap
    /// would break requests already sized for the current signature.
    Incompatible(String),
}

impl std::fmt::Display for SwapError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SwapError::UnknownModel(name) => write!(f, "unknown model '{name}'"),
            SwapError::Incompatible(msg) => write!(f, "incompatible model: {msg}"),
        }
    }
}

impl std::error::Error for SwapError {}

struct RegistryInner {
    models: BTreeMap<String, Arc<ModelVersion>>,
    active: Arc<ModelVersion>,
}

/// Thread-shared model store. See the module docs for the swap
/// semantics.
pub struct ModelRegistry {
    spx: SpxConfig,
    /// Bumped on every [`ModelRegistry::activate`]; backends compare it
    /// against the generation they last refreshed at.
    generation: AtomicU64,
    inner: Mutex<RegistryInner>,
}

impl ModelRegistry {
    /// Create a registry with `mlp` registered under `name` (version 1)
    /// and active. `spx` is used to quantize every model registered
    /// through [`ModelRegistry::register_mlp`].
    pub fn new(name: &str, mlp: Mlp, spx: SpxConfig) -> Arc<ModelRegistry> {
        let quantized = QuantizedMlp::from_mlp(&mlp, &spx, Calibration::MaxAbs, None);
        let first = Arc::new(ModelVersion { name: name.to_string(), version: 1, mlp, quantized });
        let mut models = BTreeMap::new();
        models.insert(name.to_string(), first.clone());
        Arc::new(ModelRegistry {
            spx,
            generation: AtomicU64::new(1),
            inner: Mutex::new(RegistryInner { models, active: first }),
        })
    }

    /// Register (or re-register, bumping the version) a model under
    /// `name` without activating it.
    pub fn register_mlp(&self, name: &str, mlp: Mlp) -> Arc<ModelVersion> {
        let quantized = QuantizedMlp::from_mlp(&mlp, &self.spx, Calibration::MaxAbs, None);
        let mut inner = self.inner.lock().unwrap();
        let version = inner.models.get(name).map(|m| m.version + 1).unwrap_or(1);
        let model =
            Arc::new(ModelVersion { name: name.to_string(), version, mlp, quantized });
        inner.models.insert(name.to_string(), model.clone());
        model
    }

    /// Atomically make `name` the active model. Fails if the name is
    /// unknown or its I/O signature differs from the active model's.
    /// Returns the model and the new generation.
    pub fn activate(&self, name: &str) -> Result<(Arc<ModelVersion>, u64), SwapError> {
        let mut inner = self.inner.lock().unwrap();
        let model = inner
            .models
            .get(name)
            .cloned()
            .ok_or_else(|| SwapError::UnknownModel(name.to_string()))?;
        let active = &inner.active;
        if model.input_dim() != active.input_dim() || model.output_dim() != active.output_dim()
        {
            return Err(SwapError::Incompatible(format!(
                "'{name}' is {}→{}, active '{}' is {}→{}",
                model.input_dim(),
                model.output_dim(),
                active.name,
                active.input_dim(),
                active.output_dim()
            )));
        }
        inner.active = model.clone();
        // The generation bump happens under the lock so a backend that
        // observes the new counter also observes the new active model.
        let generation = self.generation.fetch_add(1, Ordering::SeqCst) + 1;
        Ok((model, generation))
    }

    /// The currently active model.
    pub fn active(&self) -> Arc<ModelVersion> {
        self.inner.lock().unwrap().active.clone()
    }

    /// Current swap generation (starts at 1, bumped per activate).
    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::SeqCst)
    }

    /// Registered model names.
    pub fn names(&self) -> Vec<String> {
        self.inner.lock().unwrap().models.keys().cloned().collect()
    }

    /// Look up a registered model without activating it.
    pub fn get(&self, name: &str) -> Option<Arc<ModelVersion>> {
        self.inner.lock().unwrap().models.get(name).cloned()
    }

    /// Persist `name`'s latest version: the fp32 tensors plus SPx
    /// sidecar tensors (level indices, scales, data ranges, term bits).
    pub fn save_blob(&self, name: &str, path: &Path) -> Result<()> {
        let model = self.get(name).with_context(|| format!("unknown model '{name}'"))?;
        let mut tensors = model.mlp.to_tensors();
        tensors.push(NamedTensor::new(
            "spx_term_bits",
            vec![model.quantized.layers[0].w.config.num_terms()],
            model.quantized.layers[0]
                .w
                .config
                .term_bits
                .iter()
                .map(|&b| b as f32)
                .collect(),
        ));
        for (i, layer) in model.quantized.layers.iter().enumerate() {
            tensors.push(NamedTensor::new(
                format!("spx_idx{i}"),
                layer.w.shape.clone(),
                layer.w.indices.iter().map(|&ix| ix as f32).collect(),
            ));
            tensors.push(NamedTensor::new(format!("spx_scale{i}"), vec![1], vec![layer.w.scale]));
            tensors.push(NamedTensor::new(
                format!("spx_dscale{i}"),
                vec![1],
                vec![layer.d_scale],
            ));
        }
        save_tensors(path, &tensors)
    }

    /// Load a blob written by [`ModelRegistry::save_blob`] (or a plain
    /// `Mlp::save` checkpoint, which is then quantized with the
    /// registry's SPx config) and register it under `name`.
    pub fn load_blob(&self, name: &str, path: &Path) -> Result<Arc<ModelVersion>> {
        let tensors =
            load_tensors(path).with_context(|| format!("load model blob {}", path.display()))?;
        let mlp = Mlp::from_tensors(&tensors)?;
        let find = |tag: &str| tensors.iter().find(|t| t.name == tag);
        let quantized = match find("spx_term_bits") {
            None => QuantizedMlp::from_mlp(&mlp, &self.spx, Calibration::MaxAbs, None),
            Some(bits) => {
                // Validate before SpxConfig::new / SpxCodebook::build /
                // PackedCodes, whose asserts would panic on a corrupt
                // blob (the packed layout supports at most 4 terms).
                let term_bits: Vec<u32> = bits.data.iter().map(|&b| b as u32).collect();
                if term_bits.is_empty()
                    || term_bits.len() > 4
                    || term_bits.iter().any(|&b| !(1..=7).contains(&b))
                {
                    bail!("spx_term_bits {:?} out of range", bits.data);
                }
                let config = SpxConfig::new(term_bits);
                let mut layers = Vec::with_capacity(mlp.layers.len());
                for (i, layer) in mlp.layers.iter().enumerate() {
                    let idx = find(&format!("spx_idx{i}"))
                        .with_context(|| format!("blob missing spx_idx{i}"))?;
                    let scale = find(&format!("spx_scale{i}"))
                        .with_context(|| format!("blob missing spx_scale{i}"))?;
                    let d_scale = find(&format!("spx_dscale{i}"))
                        .with_context(|| format!("blob missing spx_dscale{i}"))?;
                    let indices: Vec<u16> = idx
                        .data
                        .iter()
                        .map(|&v| {
                            if v < 0.0 || v.fract() != 0.0 || v > u16::MAX as f32 {
                                bail!("spx_idx{i}: bad level index {v}")
                            } else {
                                Ok(v as u16)
                            }
                        })
                        .collect::<Result<_>>()?;
                    let scale_val = scale
                        .data
                        .first()
                        .copied()
                        .with_context(|| format!("spx_scale{i} is empty"))?;
                    let d_scale_val = d_scale
                        .data
                        .first()
                        .copied()
                        .with_context(|| format!("spx_dscale{i} is empty"))?;
                    let w = SpxTensor::from_parts(&config, &idx.shape, indices, scale_val)
                        .map_err(|e| anyhow::anyhow!("spx_idx{i}: {e}"))?;
                    if w.shape != vec![layer.w.rows, layer.w.cols] {
                        bail!(
                            "spx_idx{i} shape {:?} vs weight {}x{}",
                            w.shape,
                            layer.w.rows,
                            layer.w.cols
                        );
                    }
                    layers.push(QuantizedLayer {
                        w,
                        b: layer.b.clone(),
                        activation: layer.activation,
                        d_scale: d_scale_val,
                    });
                }
                QuantizedMlp { layers }
            }
        };
        let mut inner = self.inner.lock().unwrap();
        let version = inner.models.get(name).map(|m| m.version + 1).unwrap_or(1);
        let model = Arc::new(ModelVersion {
            name: name.to_string(),
            version,
            mlp,
            quantized,
        });
        inner.models.insert(name.to_string(), model.clone());
        Ok(model)
    }
}

// ---------------------------------------------------------------------------
// Swappable backends: coordinator backends that refresh themselves from
// the registry between batches.
// ---------------------------------------------------------------------------

/// CPU backend following the registry's active model.
pub struct SwappableCpuBackend {
    registry: Arc<ModelRegistry>,
    seen: u64,
    inner: CpuBackend,
}

impl SwappableCpuBackend {
    pub fn new(registry: Arc<ModelRegistry>) -> Self {
        let seen = registry.generation();
        let inner = CpuBackend::new(registry.active().mlp.clone());
        SwappableCpuBackend { registry, seen, inner }
    }

    fn refresh(&mut self) {
        let generation = self.registry.generation();
        if generation != self.seen {
            self.inner = CpuBackend::new(self.registry.active().mlp.clone());
            self.seen = generation;
        }
    }
}

impl Backend for SwappableCpuBackend {
    fn name(&self) -> &str {
        self.inner.name()
    }

    fn max_batch(&self) -> usize {
        self.inner.max_batch()
    }

    fn infer(&mut self, inputs: &[Vec<f32>]) -> Result<(Vec<Vec<f32>>, Option<CycleStats>)> {
        self.refresh();
        self.inner.infer(inputs)
    }
}

/// FPGA-simulator backend following the registry's active model: a swap
/// rebuilds the [`Accelerator`] (decoded-weight caches and all) from
/// the new version's SPx tensors.
pub struct SwappableFpgaBackend {
    registry: Arc<ModelRegistry>,
    config: AccelConfig,
    seen: u64,
    inner: FpgaBackend,
}

impl SwappableFpgaBackend {
    pub fn new(registry: Arc<ModelRegistry>, config: AccelConfig) -> Self {
        let seen = registry.generation();
        let accel = Accelerator::new(registry.active().quantized.clone(), config);
        SwappableFpgaBackend { registry, config, seen, inner: FpgaBackend::new(accel) }
    }

    fn refresh(&mut self) {
        let generation = self.registry.generation();
        if generation != self.seen {
            let accel = Accelerator::new(self.registry.active().quantized.clone(), self.config);
            self.inner = FpgaBackend::new(accel);
            self.seen = generation;
        }
    }
}

impl Backend for SwappableFpgaBackend {
    fn name(&self) -> &str {
        self.inner.name()
    }

    fn max_batch(&self) -> usize {
        self.inner.max_batch()
    }

    fn infer(&mut self, inputs: &[Vec<f32>]) -> Result<(Vec<Vec<f32>>, Option<CycleStats>)> {
        self.refresh();
        self.inner.infer(inputs)
    }
}

/// Coordinator factory for a registry-backed CPU worker.
pub fn swappable_cpu_factory(registry: Arc<ModelRegistry>) -> BackendFactory {
    Box::new(move || Ok(Box::new(SwappableCpuBackend::new(registry)) as Box<dyn Backend>))
}

/// Coordinator factory for a registry-backed FPGA-sim worker.
pub fn swappable_fpga_factory(
    registry: Arc<ModelRegistry>,
    config: AccelConfig,
) -> BackendFactory {
    Box::new(move || {
        Ok(Box::new(SwappableFpgaBackend::new(registry, config)) as Box<dyn Backend>)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::mlp::MlpConfig;
    use crate::nn::activations::Activation;
    use crate::util::rng::Pcg32;
    use std::path::PathBuf;

    fn small_mlp(seed: u64) -> Mlp {
        let mut rng = Pcg32::new(seed);
        Mlp::new(
            MlpConfig {
                sizes: vec![8, 6, 3],
                activations: vec![Activation::Sigmoid, Activation::Sigmoid],
            },
            &mut rng,
        )
    }

    fn registry() -> Arc<ModelRegistry> {
        ModelRegistry::new("default", small_mlp(1), SpxConfig::sp2(5))
    }

    struct TestFile(PathBuf);

    impl TestFile {
        fn new(tag: &str) -> TestFile {
            TestFile(std::env::temp_dir().join(format!(
                "edgemlp_model_{tag}_{}_{}.emlp",
                std::process::id(),
                std::time::SystemTime::now()
                    .duration_since(std::time::UNIX_EPOCH)
                    .unwrap()
                    .subsec_nanos()
            )))
        }
    }

    impl Drop for TestFile {
        fn drop(&mut self) {
            let _ = std::fs::remove_file(&self.0);
        }
    }

    #[test]
    fn register_and_activate_bumps_generation() {
        let reg = registry();
        assert_eq!(reg.generation(), 1);
        assert_eq!(reg.active().version, 1);
        let v = reg.register_mlp("retrained", small_mlp(2));
        assert_eq!(v.version, 1);
        // Re-register under the same name bumps the version.
        assert_eq!(reg.register_mlp("retrained", small_mlp(3)).version, 2);
        let (model, generation) = reg.activate("retrained").unwrap();
        assert_eq!(model.version, 2);
        assert_eq!(generation, 2);
        assert_eq!(reg.active().name, "retrained");
        assert_eq!(reg.names(), vec!["default".to_string(), "retrained".to_string()]);
    }

    #[test]
    fn activate_unknown_and_incompatible_rejected() {
        let reg = registry();
        assert!(matches!(
            reg.activate("nope"),
            Err(SwapError::UnknownModel(name)) if name == "nope"
        ));
        let mut rng = Pcg32::new(9);
        let wide = Mlp::new(
            MlpConfig { sizes: vec![16, 4, 3], activations: vec![Activation::Sigmoid; 2] },
            &mut rng,
        );
        reg.register_mlp("wide", wide);
        assert!(matches!(reg.activate("wide"), Err(SwapError::Incompatible(_))));
        // A refused swap leaves the active model and generation alone.
        assert_eq!(reg.active().name, "default");
        assert_eq!(reg.generation(), 1);
    }

    #[test]
    fn blob_roundtrip_preserves_quantized_model_bitwise() {
        let reg = registry();
        let file = TestFile::new("roundtrip");
        reg.save_blob("default", &file.0).unwrap();
        let back = reg.load_blob("reloaded", &file.0).unwrap();
        let orig = reg.get("default").unwrap();
        for (a, b) in back.quantized.layers.iter().zip(&orig.quantized.layers) {
            assert_eq!(a.w.decode(), b.w.decode());
            assert_eq!(a.w.indices, b.w.indices);
            assert_eq!(a.d_scale, b.d_scale);
            assert_eq!(a.b, b.b);
        }
        assert_eq!(back.mlp.layers[0].w.data, orig.mlp.layers[0].w.data);
    }

    #[test]
    fn plain_checkpoint_loads_and_requantizes() {
        let reg = registry();
        let file = TestFile::new("plain");
        small_mlp(4).save(&file.0).unwrap();
        let model = reg.load_blob("ckpt", &file.0).unwrap();
        assert_eq!(model.quantized.layers.len(), 2);
        assert_eq!(model.input_dim(), 8);
    }

    #[test]
    fn swappable_backends_follow_activation() {
        let reg = registry();
        let v2 = small_mlp(2);
        reg.register_mlp("v2", v2.clone());
        let x = vec![0.4f32; 8];

        let mut cpu = SwappableCpuBackend::new(reg.clone());
        let (before, _) = cpu.infer(&[x.clone()]).unwrap();
        assert_eq!(before[0], reg.get("default").unwrap().mlp.forward_one(&x));

        let mut fpga =
            SwappableFpgaBackend::new(reg.clone(), AccelConfig::default_fpga());
        let (fpga_before, _) = fpga.infer(&[x.clone()]).unwrap();

        reg.activate("v2").unwrap();
        let (after, _) = cpu.infer(&[x.clone()]).unwrap();
        assert_eq!(after[0], v2.forward_one(&x));
        assert_ne!(before[0], after[0], "swap did not change cpu outputs");

        let (fpga_after, _) = fpga.infer(&[x.clone()]).unwrap();
        assert_ne!(fpga_before[0], fpga_after[0], "swap did not change fpga outputs");
    }
}
