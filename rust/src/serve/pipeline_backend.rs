//! The stage-pipelined execution backend: one dedicated thread per MLP
//! layer, bounded SPSC channels between them, up to `depth` micro-
//! batches in flight — the software analogue of the paper's §3.1 PU
//! stagger at layer granularity (docs/pipelined-engine.md).
//!
//! A submitted batch is split into ≤ `depth` contiguous row chunks and
//! streamed through the layer chain: chunk *i* is in layer *k* while
//! chunk *i+1* is in layer *k−1*, so every stage (core) stays busy once
//! the pipeline fills. Each stage owns a clone of its layer's weights
//! plus the job-resident ping/pong activation buffers, and calls the
//! *same* per-layer entry point the monolithic paths use —
//! [`crate::nn::mlp::Layer::forward_into`] for f32,
//! [`crate::fpga::accelerator::QuantizedLayer::forward_batch_into`] for
//! SPx — on the same process-wide dispatch path.
//!
//! **Bitwise contract**: outputs equal [`crate::nn::Mlp::forward_with`]
//! / [`crate::fpga::accelerator::Accelerator::forward_batch`] bit for
//! bit at every depth. Chunking is safe because the blocked GEMM
//! accumulates every output element in a fixed k-order that neither the
//! row count nor the band plan can change (pinned by
//! `forward_rows_bitwise_stable_under_chunking` in `nn/mlp.rs`), and
//! the SPx datapath is exact integer arithmetic. The randomized
//! conformance suite (`rust/tests/conformance.rs`) pins the contract
//! across shapes, batch sizes, dispatch paths and depths 1..4.
//!
//! Fault containment: a panicking stage fails only the chunks of the
//! batch it was processing — [`Backend::infer`] returns `Err` for that
//! batch (error responses for its requests), the stage threads survive,
//! and the next batch proceeds normally (`tests/fault_injection.rs`).

use super::registry::ModelSlot;
use crate::coordinator::backend::{stage_inputs, Backend};
use crate::coordinator::server::SharedBackendFactory;
use crate::fpga::accelerator::{AccelConfig, Accelerator};
use crate::fpga::stats::CycleStats;
use crate::nn::kernels::pipeline::{StageError, StageFn, StagePipeline, StageSnapshot};
use crate::nn::tensor::Matrix;
use crate::nn::Mlp;
use crate::obs::trace::TraceRecorder;
use anyhow::{bail, Result};
use std::sync::Arc;

/// A job flowing through the f32 layer chain: the chunk's activations
/// ping-pong between the two job-owned buffers, so a warm pipeline
/// allocates nothing per batch.
#[derive(Default)]
struct CpuJob {
    cur: Matrix,
    next: Matrix,
}

/// A job flowing through the SPx layer chain; carries the fixed-point
/// staging vectors [`crate::fpga::accelerator::QuantizedLayer::forward_batch_into`]
/// reuses.
#[derive(Default)]
struct SpxJob {
    cur: Matrix,
    next: Matrix,
    d_fixed: Vec<i32>,
    d_t: Vec<i32>,
}

/// Field access the shared chunk driver needs from either job type.
trait PipelineJob: Default + Send + 'static {
    fn cur(&self) -> &Matrix;
    fn cur_mut(&mut self) -> &mut Matrix;
}

impl PipelineJob for CpuJob {
    fn cur(&self) -> &Matrix {
        &self.cur
    }

    fn cur_mut(&mut self) -> &mut Matrix {
        &mut self.cur
    }
}

impl PipelineJob for SpxJob {
    fn cur(&self) -> &Matrix {
        &self.cur
    }

    fn cur_mut(&mut self) -> &mut Matrix {
        &mut self.cur
    }
}

/// Split `batch` rows into at most `depth` contiguous chunks of near-
/// equal size — the micro-batches that overlap in flight.
fn chunk_ranges(batch: usize, depth: usize) -> Vec<(usize, usize)> {
    if batch == 0 {
        return Vec::new();
    }
    let n_chunks = depth.min(batch).max(1);
    let per = batch.div_ceil(n_chunks);
    let mut out = Vec::with_capacity(n_chunks);
    let mut r0 = 0;
    while r0 < batch {
        let rows = per.min(batch - r0);
        out.push((r0, rows));
        r0 += rows;
    }
    out
}

/// Stream `x` through the pipeline in row chunks and reassemble the
/// output in submission order. On a stage panic the remaining chunks
/// are still drained (the pipeline stays aligned for the next batch)
/// and the whole batch reports the stage error.
fn run_chunks<J: PipelineJob>(
    pipe: &StagePipeline<J>,
    free: &mut Vec<J>,
    x: &Matrix,
    out_dim: usize,
) -> Result<Matrix> {
    let chunks = chunk_ranges(x.rows, pipe.depth());
    for &(r0, rows) in &chunks {
        let mut job = free.pop().unwrap_or_default();
        let cur = job.cur_mut();
        cur.resize_zeroed(rows, x.cols);
        cur.data.copy_from_slice(&x.data[r0 * x.cols..(r0 + rows) * x.cols]);
        if !pipe.submit(job) {
            bail!("stage pipeline is shut down");
        }
    }
    let mut out = Matrix::zeros(x.rows, out_dim);
    let mut failure: Option<StageError> = None;
    for &(r0, rows) in &chunks {
        match pipe.recv() {
            None => bail!("stage pipeline closed mid-batch"),
            Some(Err(e)) => failure = Some(e),
            Some(Ok(job)) => {
                let cur = job.cur();
                debug_assert_eq!((cur.rows, cur.cols), (rows, out_dim));
                out.data[r0 * out_dim..(r0 + rows) * out_dim].copy_from_slice(&cur.data);
                free.push(job);
            }
        }
    }
    if let Some(e) = failure {
        bail!("{e}");
    }
    Ok(out)
}

/// Stage-pipelined f32 backend: per-layer stage threads over
/// [`crate::nn::mlp::Layer::forward_into`]. Output is bitwise identical
/// to [`Mlp::forward_with`] at every depth.
pub struct PipelineCpuBackend {
    pub mlp: Mlp,
    name: String,
    pipe: StagePipeline<CpuJob>,
    staging: Matrix,
    free: Vec<CpuJob>,
}

impl PipelineCpuBackend {
    pub fn new(mlp: Mlp, depth: usize) -> Self {
        Self::new_traced(mlp, depth, None)
    }

    /// [`PipelineCpuBackend::new`] with a trace recorder: each layer
    /// stage emits a `"run"` span per chunk onto track
    /// `"cpu-pipe/layer<i>"`.
    pub fn new_traced(mlp: Mlp, depth: usize, tracer: Option<Arc<TraceRecorder>>) -> Self {
        let mut stages: Vec<(String, StageFn<CpuJob>)> = Vec::with_capacity(mlp.layers.len());
        for (i, layer) in mlp.layers.iter().enumerate() {
            // The stage thread owns its layer's weights: the clone moves
            // into the stage closure.
            let layer = layer.clone();
            let f: StageFn<CpuJob> = Box::new(move |job| {
                layer.forward_into(&job.cur, &mut job.next);
                std::mem::swap(&mut job.cur, &mut job.next);
            });
            stages.push((format!("layer{i}"), f));
        }
        PipelineCpuBackend {
            mlp,
            name: "pipeline".into(),
            pipe: StagePipeline::new_traced("cpu-pipe", depth, stages, tracer),
            staging: Matrix::zeros(0, 0),
            free: Vec::new(),
        }
    }

    /// In-flight micro-batch bound the pipeline was built with.
    pub fn depth(&self) -> usize {
        self.pipe.depth()
    }

    /// Batched forward through the stage pipeline — what the
    /// conformance suite compares bitwise against
    /// [`Mlp::forward_with`].
    pub fn forward_batch(&mut self, x: &Matrix) -> Result<Matrix> {
        assert_eq!(x.cols, self.mlp.input_dim(), "input dim");
        run_chunks(&self.pipe, &mut self.free, x, self.mlp.output_dim())
    }
}

impl Backend for PipelineCpuBackend {
    fn name(&self) -> &str {
        &self.name
    }

    fn max_batch(&self) -> usize {
        256
    }

    fn infer(&mut self, inputs: &[Vec<f32>]) -> Result<(Vec<Vec<f32>>, Option<CycleStats>)> {
        stage_inputs(&mut self.staging, inputs, self.mlp.input_dim())?;
        let y = run_chunks(&self.pipe, &mut self.free, &self.staging, self.mlp.output_dim())?;
        let out = (0..inputs.len()).map(|r| y.row(r).to_vec()).collect();
        Ok((out, None))
    }

    fn stage_stats(&self) -> Option<Vec<StageSnapshot>> {
        Some(self.pipe.snapshots())
    }

    fn calibration_input(&self) -> Option<Vec<f32>> {
        Some(vec![0.0; self.mlp.input_dim()])
    }
}

/// Stage-pipelined SPx backend: per-layer stage threads over
/// [`crate::fpga::accelerator::QuantizedLayer::forward_batch_into`].
/// Output is bitwise identical to [`Accelerator::forward_batch`] at
/// every depth; simulator stats are the same data-independent
/// `trace × B` accounting [`Accelerator::infer_batch`] reports.
pub struct PipelineFpgaBackend {
    pub accel: Accelerator,
    name: String,
    pipe: StagePipeline<SpxJob>,
    staging: Matrix,
    free: Vec<SpxJob>,
}

impl PipelineFpgaBackend {
    pub fn new(accel: Accelerator, depth: usize) -> Self {
        Self::new_traced(accel, depth, None)
    }

    /// [`PipelineFpgaBackend::new`] with a trace recorder: each layer
    /// stage emits a `"run"` span per chunk onto track
    /// `"fpga-pipe/layer<i>"`.
    pub fn new_traced(
        accel: Accelerator,
        depth: usize,
        tracer: Option<Arc<TraceRecorder>>,
    ) -> Self {
        let n_layers = accel.model.layers.len();
        let mut stages: Vec<(String, StageFn<SpxJob>)> = Vec::with_capacity(n_layers);
        for (i, layer) in accel.model.layers.iter().enumerate() {
            let layer = layer.clone();
            let f: StageFn<SpxJob> = Box::new(move |job| {
                layer.forward_batch_into(&job.cur, &mut job.next, &mut job.d_fixed, &mut job.d_t);
                std::mem::swap(&mut job.cur, &mut job.next);
            });
            stages.push((format!("layer{i}"), f));
        }
        PipelineFpgaBackend {
            name: "pipeline-fpga".into(),
            pipe: StagePipeline::new_traced("fpga-pipe", depth, stages, tracer),
            staging: Matrix::zeros(0, 0),
            free: Vec::new(),
            accel,
        }
    }

    fn input_dim(&self) -> usize {
        self.accel.model.layers[0].w.shape[1]
    }

    fn output_dim(&self) -> usize {
        self.accel.model.layers.last().unwrap().w.shape[0]
    }

    pub fn depth(&self) -> usize {
        self.pipe.depth()
    }

    /// Batched forward through the stage pipeline — what the
    /// conformance suite compares bitwise against
    /// [`Accelerator::forward_batch`].
    pub fn forward_batch(&mut self, x: &Matrix) -> Result<Matrix> {
        assert_eq!(x.cols, self.input_dim(), "input dim");
        let out_dim = self.output_dim();
        run_chunks(&self.pipe, &mut self.free, x, out_dim)
    }
}

impl Backend for PipelineFpgaBackend {
    fn name(&self) -> &str {
        &self.name
    }

    fn max_batch(&self) -> usize {
        64
    }

    fn infer(&mut self, inputs: &[Vec<f32>]) -> Result<(Vec<Vec<f32>>, Option<CycleStats>)> {
        stage_inputs(&mut self.staging, inputs, self.input_dim())?;
        let out_dim = self.output_dim();
        let y = run_chunks(&self.pipe, &mut self.free, &self.staging, out_dim)?;
        let out = (0..inputs.len()).map(|r| y.row(r).to_vec()).collect();
        Ok((out, Some(self.accel.batch_stats(inputs.len()))))
    }

    fn stage_stats(&self) -> Option<Vec<StageSnapshot>> {
        Some(self.pipe.snapshots())
    }

    fn calibration_input(&self) -> Option<Vec<f32>> {
        Some(vec![0.0; self.input_dim()])
    }
}

/// Stage-pipelined CPU backend following a slot's active model: a swap
/// tears down the old stage threads and rebuilds the pipeline from the
/// new version between batches (same generation protocol as
/// [`super::registry::SwappableCpuBackend`]).
pub struct SwappablePipelineCpuBackend {
    slot: Arc<ModelSlot>,
    depth: usize,
    seen: u64,
    tracer: Option<Arc<TraceRecorder>>,
    inner: PipelineCpuBackend,
}

impl SwappablePipelineCpuBackend {
    pub fn new(slot: Arc<ModelSlot>, depth: usize) -> Self {
        Self::new_traced(slot, depth, None)
    }

    /// Trace-capable variant; the recorder survives swaps (each rebuilt
    /// pipeline keeps emitting onto the same ring).
    pub fn new_traced(
        slot: Arc<ModelSlot>,
        depth: usize,
        tracer: Option<Arc<TraceRecorder>>,
    ) -> Self {
        let seen = slot.generation();
        let inner =
            PipelineCpuBackend::new_traced(slot.active().mlp.clone(), depth, tracer.clone());
        SwappablePipelineCpuBackend { slot, depth, seen, tracer, inner }
    }

    fn refresh(&mut self) {
        let generation = self.slot.generation();
        if generation != self.seen {
            self.inner = PipelineCpuBackend::new_traced(
                self.slot.active().mlp.clone(),
                self.depth,
                self.tracer.clone(),
            );
            self.seen = generation;
        }
    }
}

impl Backend for SwappablePipelineCpuBackend {
    fn name(&self) -> &str {
        self.inner.name()
    }

    fn max_batch(&self) -> usize {
        self.inner.max_batch()
    }

    fn infer(&mut self, inputs: &[Vec<f32>]) -> Result<(Vec<Vec<f32>>, Option<CycleStats>)> {
        self.refresh();
        self.inner.infer(inputs)
    }

    fn stage_stats(&self) -> Option<Vec<StageSnapshot>> {
        self.inner.stage_stats()
    }

    fn calibration_input(&self) -> Option<Vec<f32>> {
        self.inner.calibration_input()
    }
}

/// Stage-pipelined SPx backend following a slot's active model.
pub struct SwappablePipelineFpgaBackend {
    slot: Arc<ModelSlot>,
    config: AccelConfig,
    depth: usize,
    seen: u64,
    tracer: Option<Arc<TraceRecorder>>,
    inner: PipelineFpgaBackend,
}

impl SwappablePipelineFpgaBackend {
    pub fn new(slot: Arc<ModelSlot>, config: AccelConfig, depth: usize) -> Self {
        Self::new_traced(slot, config, depth, None)
    }

    /// Trace-capable variant; the recorder survives swaps.
    pub fn new_traced(
        slot: Arc<ModelSlot>,
        config: AccelConfig,
        depth: usize,
        tracer: Option<Arc<TraceRecorder>>,
    ) -> Self {
        let seen = slot.generation();
        let accel = Accelerator::new(slot.active().quantized.clone(), config);
        let inner = PipelineFpgaBackend::new_traced(accel, depth, tracer.clone());
        SwappablePipelineFpgaBackend { slot, config, depth, seen, tracer, inner }
    }

    fn refresh(&mut self) {
        let generation = self.slot.generation();
        if generation != self.seen {
            let accel = Accelerator::new(self.slot.active().quantized.clone(), self.config);
            self.inner = PipelineFpgaBackend::new_traced(accel, self.depth, self.tracer.clone());
            self.seen = generation;
        }
    }
}

impl Backend for SwappablePipelineFpgaBackend {
    fn name(&self) -> &str {
        self.inner.name()
    }

    fn max_batch(&self) -> usize {
        self.inner.max_batch()
    }

    fn infer(&mut self, inputs: &[Vec<f32>]) -> Result<(Vec<Vec<f32>>, Option<CycleStats>)> {
        self.refresh();
        self.inner.infer(inputs)
    }

    fn stage_stats(&self) -> Option<Vec<StageSnapshot>> {
        self.inner.stage_stats()
    }

    fn calibration_input(&self) -> Option<Vec<f32>> {
        self.inner.calibration_input()
    }
}

/// Replicable coordinator factory for slot-following stage-pipelined
/// CPU workers.
pub fn pipeline_cpu_factory(slot: Arc<ModelSlot>, depth: usize) -> SharedBackendFactory {
    pipeline_cpu_factory_traced(slot, depth, None)
}

/// [`pipeline_cpu_factory`] with a trace recorder shared by every
/// replica the coordinator builds from this factory.
pub fn pipeline_cpu_factory_traced(
    slot: Arc<ModelSlot>,
    depth: usize,
    tracer: Option<Arc<TraceRecorder>>,
) -> SharedBackendFactory {
    Arc::new(move || {
        Ok(Box::new(SwappablePipelineCpuBackend::new_traced(
            slot.clone(),
            depth,
            tracer.clone(),
        )) as Box<dyn Backend>)
    })
}

/// Replicable coordinator factory for slot-following stage-pipelined
/// SPx workers.
pub fn pipeline_fpga_factory(
    slot: Arc<ModelSlot>,
    config: AccelConfig,
    depth: usize,
) -> SharedBackendFactory {
    pipeline_fpga_factory_traced(slot, config, depth, None)
}

/// [`pipeline_fpga_factory`] with a trace recorder shared by every
/// replica the coordinator builds from this factory.
pub fn pipeline_fpga_factory_traced(
    slot: Arc<ModelSlot>,
    config: AccelConfig,
    depth: usize,
    tracer: Option<Arc<TraceRecorder>>,
) -> SharedBackendFactory {
    Arc::new(move || {
        Ok(Box::new(SwappablePipelineFpgaBackend::new_traced(
            slot.clone(),
            config,
            depth,
            tracer.clone(),
        )) as Box<dyn Backend>)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::accelerator::QuantizedMlp;
    use crate::nn::activations::Activation;
    use crate::nn::mlp::{ForwardScratch, MlpConfig};
    use crate::quant::spx::SpxConfig;
    use crate::quant::Calibration;
    use crate::serve::ModelRegistry;
    use crate::util::rng::Pcg32;

    fn small_mlp(seed: u64) -> Mlp {
        let mut rng = Pcg32::new(seed);
        Mlp::new(
            MlpConfig {
                sizes: vec![8, 6, 3],
                activations: vec![Activation::Sigmoid, Activation::Sigmoid],
            },
            &mut rng,
        )
    }

    fn assert_bitwise(a: &Matrix, b: &Matrix, ctx: &str) {
        assert_eq!((a.rows, a.cols), (b.rows, b.cols), "{ctx}: shape");
        for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: element {i}: {x} vs {y}");
        }
    }

    #[test]
    fn cpu_pipeline_bitwise_matches_forward_with() {
        let mlp = small_mlp(1);
        let mut rng = Pcg32::new(2);
        let mut scratch = ForwardScratch::new();
        for depth in 1..=4usize {
            let mut be = PipelineCpuBackend::new(mlp.clone(), depth);
            assert_eq!(be.depth(), depth);
            for &batch in &[1usize, 3, 7] {
                let x = Matrix::random_uniform(batch, 8, 1.0, &mut rng);
                let want = mlp.forward_with(&x, &mut scratch).clone();
                let got = be.forward_batch(&x).unwrap();
                assert_bitwise(&got, &want, &format!("depth {depth} batch {batch}"));
            }
        }
    }

    #[test]
    fn fpga_pipeline_bitwise_matches_forward_batch() {
        let mlp = small_mlp(3);
        let q = QuantizedMlp::from_mlp(&mlp, &SpxConfig::sp2(5), Calibration::MaxAbs, None);
        let mut rng = Pcg32::new(4);
        for depth in 1..=4usize {
            let accel = Accelerator::new(q.clone(), AccelConfig::default_fpga());
            let mut be = PipelineFpgaBackend::new(accel, depth);
            for &batch in &[1usize, 2, 6] {
                let x = Matrix::random_uniform(batch, 8, 1.0, &mut rng);
                let want = be.accel.forward_batch(&x);
                let got = be.forward_batch(&x).unwrap();
                assert_bitwise(&got, &want, &format!("depth {depth} batch {batch}"));
            }
        }
    }

    #[test]
    fn backend_infer_matches_per_sample_and_reports_stats() {
        let mlp = small_mlp(5);
        let q = QuantizedMlp::from_mlp(&mlp, &SpxConfig::sp2(5), Calibration::MaxAbs, None);
        let accel = Accelerator::new(q, AccelConfig::default_fpga());
        let mut be = PipelineFpgaBackend::new(accel, 2);
        let inputs: Vec<Vec<f32>> = (0..5).map(|i| vec![0.1 * (i as f32 + 1.0); 8]).collect();
        let (out, stats) = be.infer(&inputs).unwrap();
        assert_eq!(out.len(), 5);
        for (i, sample) in inputs.iter().enumerate() {
            let (want, _) = be.accel.infer_one(sample);
            assert_eq!(out[i], want, "sample {i}");
        }
        // Same accounting as the monolithic batched path.
        let staged = Matrix::from_vec(5, 8, inputs.concat());
        let (_, want_stats) = be.accel.infer_batch(&staged);
        assert_eq!(stats.unwrap(), want_stats);
    }

    #[test]
    fn stage_stats_cover_every_layer() {
        let mut be = PipelineCpuBackend::new(small_mlp(6), 2);
        let inputs = vec![vec![0.5f32; 8]; 4];
        be.infer(&inputs).unwrap();
        let stats = be.stage_stats().unwrap();
        assert_eq!(stats.len(), 2);
        assert_eq!(stats[0].label, "layer0");
        assert_eq!(stats[1].label, "layer1");
        // 4 samples at depth 2 split into 2 chunks per stage.
        assert_eq!(stats[0].processed, 2);
        assert_eq!(stats[1].processed, 2);
    }

    #[test]
    fn cpu_pipeline_rejects_bad_dims() {
        let mut be = PipelineCpuBackend::new(small_mlp(7), 2);
        assert!(be.infer(&[vec![0.0; 5]]).is_err());
        // The pipeline is still usable afterwards.
        let (out, _) = be.infer(&[vec![0.25; 8]]).unwrap();
        assert_eq!(out[0].len(), 3);
    }

    #[test]
    fn swappable_pipeline_backends_follow_slot_activation() {
        let reg = ModelRegistry::new("default", small_mlp(1), SpxConfig::sp2(5));
        let v2 = small_mlp(2);
        reg.register_mlp("v2", v2.clone());
        let x = vec![0.4f32; 8];
        let slot = reg.default_slot();

        let mut cpu = SwappablePipelineCpuBackend::new(slot.clone(), 2);
        let (before, _) = cpu.infer(&[x.clone()]).unwrap();
        assert_eq!(before[0], reg.get("default").unwrap().mlp.forward_one(&x));

        let mut fpga =
            SwappablePipelineFpgaBackend::new(slot.clone(), AccelConfig::default_fpga(), 2);
        let (fpga_before, _) = fpga.infer(&[x.clone()]).unwrap();

        reg.activate("v2").unwrap();
        let (after, _) = cpu.infer(&[x.clone()]).unwrap();
        assert_eq!(after[0], v2.forward_one(&x));
        assert_ne!(before[0], after[0], "swap did not change cpu outputs");
        let (fpga_after, _) = fpga.infer(&[x.clone()]).unwrap();
        assert_ne!(fpga_before[0], fpga_after[0], "swap did not change fpga outputs");
    }

    #[test]
    fn chunk_ranges_cover_the_batch_exactly() {
        for batch in 0..20usize {
            for depth in 1..6usize {
                let chunks = chunk_ranges(batch, depth);
                assert!(chunks.len() <= depth.max(1));
                let mut next = 0usize;
                for &(r0, rows) in &chunks {
                    assert_eq!(r0, next);
                    assert!(rows > 0);
                    next = r0 + rows;
                }
                assert_eq!(next, batch);
            }
        }
    }
}
