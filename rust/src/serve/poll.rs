//! Readiness polling for the event-driven connection layer: a minimal
//! epoll (Linux) / kqueue (macOS, BSDs) wrapper, the wakeup pipe that
//! lets coordinator workers nudge the loop from their threads, a
//! coarse timer wheel for read deadlines and drain budgets, and the
//! loop gauges exported on `/metrics`, `Stats`, and v4 `Health`
//! (docs/async-net.md).
//!
//! Everything here is std + self-declared libc FFI — no external
//! crates. The syscall surface is deliberately tiny: create/ctl/wait
//! on the OS readiness queue, an unnamed pipe, and `{get,set}rlimit`
//! for the file-descriptor ceiling a c10k process runs into first.

use std::io;
use std::os::unix::io::RawFd;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use super::wire::LoopGauges;

/// One readiness notification. Error/hangup conditions are folded into
/// `readable`/`writable` so the connection discovers them from the
/// next `read(2)`/`write(2)` instead of a separate code path.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
}

/// Upper bound on events drained per [`Poller::wait`] call.
pub const MAX_EVENTS: usize = 1024;

#[cfg(any(target_os = "linux", target_os = "android"))]
mod sys {
    use super::Event;
    use std::ffi::c_int;
    use std::io;
    use std::os::unix::io::RawFd;
    use std::time::Duration;

    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;
    const EPOLLRDHUP: u32 = 0x2000;
    const EPOLL_CTL_ADD: c_int = 1;
    const EPOLL_CTL_DEL: c_int = 2;
    const EPOLL_CTL_MOD: c_int = 3;
    const EPOLL_CLOEXEC: c_int = 0o2000000;

    // The kernel ABI packs this struct on x86-64 (a 12-byte layout);
    // other architectures use natural alignment.
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: c_int) -> c_int;
        fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
        fn epoll_wait(
            epfd: c_int,
            events: *mut EpollEvent,
            maxevents: c_int,
            timeout: c_int,
        ) -> c_int;
    }

    pub struct Selector {
        fd: RawFd,
    }

    impl Selector {
        pub fn new() -> io::Result<Selector> {
            let fd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if fd < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Selector { fd })
        }

        fn ctl(
            &self,
            op: c_int,
            fd: RawFd,
            token: u64,
            readable: bool,
            writable: bool,
        ) -> io::Result<()> {
            let mut events = EPOLLERR | EPOLLHUP;
            if readable {
                events |= EPOLLIN | EPOLLRDHUP;
            }
            if writable {
                events |= EPOLLOUT;
            }
            let mut ev = EpollEvent { events, data: token };
            let rc = unsafe { epoll_ctl(self.fd, op, fd, &mut ev) };
            if rc < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        pub fn add(&self, fd: RawFd, token: u64, r: bool, w: bool) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, token, r, w)
        }

        pub fn modify(&self, fd: RawFd, token: u64, r: bool, w: bool) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, token, r, w)
        }

        pub fn delete(&self, fd: RawFd) -> io::Result<()> {
            let mut ev = EpollEvent { events: 0, data: 0 };
            let rc = unsafe { epoll_ctl(self.fd, EPOLL_CTL_DEL, fd, &mut ev) };
            if rc < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        pub fn wait(&self, out: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
            let mut buf = [EpollEvent { events: 0, data: 0 }; super::MAX_EVENTS];
            let timeout_ms: c_int = match timeout {
                None => -1,
                Some(d) => d.as_millis().min(i32::MAX as u128) as c_int,
            };
            let n = unsafe {
                epoll_wait(self.fd, buf.as_mut_ptr(), super::MAX_EVENTS as c_int, timeout_ms)
            };
            if n < 0 {
                let err = io::Error::last_os_error();
                if err.kind() == io::ErrorKind::Interrupted {
                    return Ok(());
                }
                return Err(err);
            }
            for ev in buf.iter().take(n as usize) {
                // Copy out of the (possibly packed) struct before use.
                let events = ev.events;
                let data = ev.data;
                out.push(Event {
                    token: data,
                    readable: events & (EPOLLIN | EPOLLRDHUP | EPOLLERR | EPOLLHUP) != 0,
                    writable: events & (EPOLLOUT | EPOLLERR | EPOLLHUP) != 0,
                });
            }
            Ok(())
        }
    }

    impl Drop for Selector {
        fn drop(&mut self) {
            unsafe { super::close(self.fd) };
        }
    }

    extern "C" {
        fn pipe2(fds: *mut c_int, flags: c_int) -> c_int;
    }

    const O_NONBLOCK: c_int = 0o4000;
    const O_CLOEXEC: c_int = 0o2000000;

    pub fn make_pipe() -> io::Result<(RawFd, RawFd)> {
        let mut fds = [0 as c_int; 2];
        if unsafe { pipe2(fds.as_mut_ptr(), O_NONBLOCK | O_CLOEXEC) } < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok((fds[0], fds[1]))
    }

    pub const RLIMIT_NOFILE: c_int = 7;
}

#[cfg(any(
    target_os = "macos",
    target_os = "ios",
    target_os = "freebsd",
    target_os = "netbsd",
    target_os = "openbsd",
    target_os = "dragonfly"
))]
mod sys {
    use super::Event;
    use std::ffi::{c_int, c_void};
    use std::io;
    use std::os::unix::io::RawFd;
    use std::ptr;
    use std::time::Duration;

    const EVFILT_READ: i16 = -1;
    const EVFILT_WRITE: i16 = -2;
    const EV_ADD: u16 = 0x0001;
    const EV_DELETE: u16 = 0x0002;
    const EV_ENABLE: u16 = 0x0004;
    const EV_DISABLE: u16 = 0x0008;
    const EV_ERROR: u16 = 0x4000;
    const EV_EOF: u16 = 0x8000;

    #[repr(C)]
    #[derive(Clone, Copy)]
    struct Kevent {
        ident: usize,
        filter: i16,
        flags: u16,
        fflags: u32,
        data: isize,
        udata: *mut c_void,
    }

    #[repr(C)]
    struct Timespec {
        tv_sec: isize,
        tv_nsec: isize,
    }

    extern "C" {
        fn kqueue() -> c_int;
        fn kevent(
            kq: c_int,
            changelist: *const Kevent,
            nchanges: c_int,
            eventlist: *mut Kevent,
            nevents: c_int,
            timeout: *const Timespec,
        ) -> c_int;
    }

    pub struct Selector {
        fd: RawFd,
    }

    impl Selector {
        pub fn new() -> io::Result<Selector> {
            let fd = unsafe { kqueue() };
            if fd < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Selector { fd })
        }

        fn apply(&self, fd: RawFd, token: u64, r: bool, w: bool) -> io::Result<()> {
            let mk = |filter: i16, on: bool| Kevent {
                ident: fd as usize,
                filter,
                flags: EV_ADD | if on { EV_ENABLE } else { EV_DISABLE },
                fflags: 0,
                data: 0,
                udata: token as *mut c_void,
            };
            let changes = [mk(EVFILT_READ, r), mk(EVFILT_WRITE, w)];
            let rc = unsafe {
                kevent(self.fd, changes.as_ptr(), 2, ptr::null_mut(), 0, ptr::null())
            };
            if rc < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        pub fn add(&self, fd: RawFd, token: u64, r: bool, w: bool) -> io::Result<()> {
            self.apply(fd, token, r, w)
        }

        pub fn modify(&self, fd: RawFd, token: u64, r: bool, w: bool) -> io::Result<()> {
            self.apply(fd, token, r, w)
        }

        pub fn delete(&self, fd: RawFd) -> io::Result<()> {
            for filter in [EVFILT_READ, EVFILT_WRITE] {
                let change = Kevent {
                    ident: fd as usize,
                    filter,
                    flags: EV_DELETE,
                    fflags: 0,
                    data: 0,
                    udata: ptr::null_mut(),
                };
                // A filter that was never enabled reports ENOENT —
                // harmless on teardown.
                unsafe { kevent(self.fd, &change, 1, ptr::null_mut(), 0, ptr::null()) };
            }
            Ok(())
        }

        pub fn wait(&self, out: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
            let mut buf = [Kevent {
                ident: 0,
                filter: 0,
                flags: 0,
                fflags: 0,
                data: 0,
                udata: ptr::null_mut(),
            }; super::MAX_EVENTS];
            let ts;
            let ts_ptr = match timeout {
                None => ptr::null(),
                Some(d) => {
                    ts = Timespec {
                        tv_sec: d.as_secs() as isize,
                        tv_nsec: d.subsec_nanos() as isize,
                    };
                    &ts as *const Timespec
                }
            };
            let n = unsafe {
                kevent(
                    self.fd,
                    ptr::null(),
                    0,
                    buf.as_mut_ptr(),
                    super::MAX_EVENTS as c_int,
                    ts_ptr,
                )
            };
            if n < 0 {
                let err = io::Error::last_os_error();
                if err.kind() == io::ErrorKind::Interrupted {
                    return Ok(());
                }
                return Err(err);
            }
            for ev in buf.iter().take(n as usize) {
                if ev.flags & EV_ERROR != 0 {
                    continue;
                }
                let eof = ev.flags & EV_EOF != 0;
                out.push(Event {
                    token: ev.udata as u64,
                    readable: ev.filter == EVFILT_READ || eof,
                    writable: ev.filter == EVFILT_WRITE || eof,
                });
            }
            Ok(())
        }
    }

    impl Drop for Selector {
        fn drop(&mut self) {
            unsafe { super::close(self.fd) };
        }
    }

    extern "C" {
        fn pipe(fds: *mut c_int) -> c_int;
        fn fcntl(fd: c_int, cmd: c_int, arg: c_int) -> c_int;
    }

    const F_SETFD: c_int = 2;
    const F_SETFL: c_int = 4;
    const FD_CLOEXEC: c_int = 1;
    const O_NONBLOCK: c_int = 0x0004;

    pub fn make_pipe() -> io::Result<(RawFd, RawFd)> {
        let mut fds = [0 as c_int; 2];
        if unsafe { pipe(fds.as_mut_ptr()) } < 0 {
            return Err(io::Error::last_os_error());
        }
        for fd in fds {
            unsafe {
                fcntl(fd, F_SETFL, O_NONBLOCK);
                fcntl(fd, F_SETFD, FD_CLOEXEC);
            }
        }
        Ok((fds[0], fds[1]))
    }

    pub const RLIMIT_NOFILE: std::ffi::c_int = 8;
}

extern "C" {
    fn close(fd: std::ffi::c_int) -> std::ffi::c_int;
    fn read(fd: std::ffi::c_int, buf: *mut u8, count: usize) -> isize;
    fn write(fd: std::ffi::c_int, buf: *const u8, count: usize) -> isize;
    fn getrlimit(resource: std::ffi::c_int, rlim: *mut Rlimit) -> std::ffi::c_int;
    fn setrlimit(resource: std::ffi::c_int, rlim: *const Rlimit) -> std::ffi::c_int;
}

#[repr(C)]
struct Rlimit {
    cur: u64,
    max: u64,
}

/// Raise the soft `RLIMIT_NOFILE` toward `want` (clamped to the hard
/// limit) and return the effective soft limit. c10k needs fds, not
/// threads: each in-process client/server connection pair costs two.
/// Best-effort — callers clamp their connection counts to the result.
pub fn raise_nofile_limit(want: u64) -> u64 {
    unsafe {
        let mut lim = Rlimit { cur: 0, max: 0 };
        if getrlimit(sys::RLIMIT_NOFILE, &mut lim) != 0 {
            return 0;
        }
        if lim.cur >= want {
            return lim.cur;
        }
        let target = want.min(lim.max);
        let new = Rlimit { cur: target, max: lim.max };
        if setrlimit(sys::RLIMIT_NOFILE, &new) == 0 {
            target
        } else {
            lim.cur
        }
    }
}

/// OS readiness queue behind a poller-shaped API. Level-triggered on
/// both platforms: an event repeats every wait until the condition
/// (unread bytes, writable buffer space) is consumed.
pub struct Poller {
    selector: sys::Selector,
}

impl Poller {
    pub fn new() -> io::Result<Poller> {
        Ok(Poller { selector: sys::Selector::new()? })
    }

    pub fn add(&self, fd: RawFd, token: u64, readable: bool, writable: bool) -> io::Result<()> {
        self.selector.add(fd, token, readable, writable)
    }

    pub fn modify(&self, fd: RawFd, token: u64, readable: bool, writable: bool) -> io::Result<()> {
        self.selector.modify(fd, token, readable, writable)
    }

    pub fn delete(&self, fd: RawFd) -> io::Result<()> {
        self.selector.delete(fd)
    }

    /// Block until readiness or `timeout`, appending events to `out`
    /// (cleared first). A signal interruption returns empty, not an
    /// error — callers just re-poll.
    pub fn wait(&self, out: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
        out.clear();
        self.selector.wait(out, timeout)
    }
}

/// Self-pipe waker: coordinator workers (and `Server::shutdown`) write
/// one byte from their threads; the loop has the read end registered
/// and drains it on wakeup. Writes into a full pipe are dropped — a
/// full pipe already guarantees a pending wakeup.
pub struct WakePipe {
    read_fd: RawFd,
    write_fd: RawFd,
}

impl WakePipe {
    pub fn new() -> io::Result<WakePipe> {
        let (r, w) = sys::make_pipe()?;
        Ok(WakePipe { read_fd: r, write_fd: w })
    }

    /// The fd to register with the [`Poller`].
    pub fn read_fd(&self) -> RawFd {
        self.read_fd
    }

    /// Nudge the loop. Safe from any thread; `write(2)` on a pipe is
    /// atomic for single bytes.
    pub fn wake(&self) {
        let byte = [1u8];
        unsafe { write(self.write_fd, byte.as_ptr(), 1) };
    }

    /// Swallow all pending wakeup bytes (called by the loop once per
    /// readiness event on the read end).
    pub fn drain(&self) {
        let mut buf = [0u8; 64];
        loop {
            let n = unsafe { read(self.read_fd, buf.as_mut_ptr(), buf.len()) };
            if n <= 0 {
                return;
            }
        }
    }
}

impl Drop for WakePipe {
    fn drop(&mut self) {
        unsafe {
            close(self.read_fd);
            close(self.write_fd);
        }
    }
}

/// Loop-owned counters exported as gauges. Shared as `Arc<LoopStats>`
/// between the event loop (writer) and the metrics/Stats/Health render
/// paths (readers); all accesses relaxed — these are monitoring
/// signals, not synchronization.
#[derive(Debug, Default)]
pub struct LoopStats {
    pub registered_conns: AtomicU64,
    pub ready_events: AtomicU64,
    pub poll_ticks: AtomicU64,
    pub pending_writeback_bytes: AtomicU64,
    pub timer_depth: AtomicU64,
}

impl LoopStats {
    pub fn gauges(&self) -> LoopGauges {
        LoopGauges {
            registered_conns: self.registered_conns.load(Ordering::Relaxed),
            ready_events: self.ready_events.load(Ordering::Relaxed),
            poll_ticks: self.poll_ticks.load(Ordering::Relaxed),
            pending_writeback_bytes: self.pending_writeback_bytes.load(Ordering::Relaxed),
            timer_depth: self.timer_depth.load(Ordering::Relaxed),
        }
    }
}

/// Hashed-wheel timer with a fixed tick. Entries are `(token,
/// generation)` hints, not authoritative deadlines: when one fires the
/// loop re-checks the connection's actual deadline and reschedules if
/// it moved (per-frame deadline restarts never touch the wheel).
/// Deadlines beyond the wheel horizon land in the furthest slot and
/// re-arm on fire, so arbitrarily long `--read-timeout-s` values work.
pub struct TimerWheel {
    slots: Vec<Vec<(u64, u64)>>,
    tick: Duration,
    cursor: usize,
    origin: Instant,
    live: usize,
}

impl TimerWheel {
    pub fn new(nslots: usize, tick: Duration, now: Instant) -> TimerWheel {
        assert!(nslots >= 2 && tick > Duration::ZERO);
        TimerWheel {
            slots: (0..nslots).map(|_| Vec::new()).collect(),
            tick,
            cursor: 0,
            origin: now,
            live: 0,
        }
    }

    pub fn depth(&self) -> usize {
        self.live
    }

    /// Arm a `(token, generation)` entry to fire at or shortly after
    /// `deadline` (granularity: one tick). Uses ceiling division so a
    /// sub-tick or already-lapsed deadline fires on the very next
    /// `advance` instead of a full slot later; the floor is one tick
    /// because `advance` steps the cursor before draining, so the
    /// current slot would otherwise wait a whole wheel revolution.
    pub fn schedule(&mut self, now: Instant, deadline: Instant, token: u64, generation: u64) {
        let delay = deadline.saturating_duration_since(now);
        let tick_ns = self.tick.as_nanos();
        let ticks = delay.as_nanos().div_ceil(tick_ns).max(1);
        let ticks = ticks.min(self.slots.len() as u128 - 1) as usize;
        let slot = (self.cursor + ticks) % self.slots.len();
        self.slots[slot].push((token, generation));
        self.live += 1;
    }

    /// Advance the wheel to `now`, draining every slot whose time has
    /// come into `fired`.
    pub fn advance(&mut self, now: Instant, fired: &mut Vec<(u64, u64)>) {
        let tick_ns = self.tick.as_nanos();
        let steps = now.saturating_duration_since(self.origin).as_nanos() / tick_ns;
        if steps == 0 {
            return;
        }
        for _ in 0..steps.min(self.slots.len() as u128) {
            self.cursor = (self.cursor + 1) % self.slots.len();
            let drained = std::mem::take(&mut self.slots[self.cursor]);
            self.live -= drained.len();
            fired.extend(drained);
        }
        let advanced = tick_ns.saturating_mul(steps);
        self.origin += Duration::from_nanos(advanced.min(u64::MAX as u128) as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;
    use std::net::{TcpListener, TcpStream};
    use std::os::unix::io::AsRawFd;

    #[test]
    fn poller_reports_readability_and_wakeups() {
        let poller = Poller::new().unwrap();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let mut client = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();
        poller.add(server.as_raw_fd(), 7, true, false).unwrap();

        let mut events = Vec::new();
        poller.wait(&mut events, Some(Duration::from_millis(10))).unwrap();
        assert!(events.is_empty(), "no bytes yet, no readiness");

        client.write_all(b"x").unwrap();
        poller.wait(&mut events, Some(Duration::from_secs(2))).unwrap();
        assert!(events.iter().any(|e| e.token == 7 && e.readable));

        // Level-triggered: unread bytes keep the event repeating.
        poller.wait(&mut events, Some(Duration::from_millis(50))).unwrap();
        assert!(events.iter().any(|e| e.token == 7 && e.readable));

        // Writable interest on an idle socket fires immediately.
        poller.modify(server.as_raw_fd(), 7, true, true).unwrap();
        poller.wait(&mut events, Some(Duration::from_secs(2))).unwrap();
        assert!(events.iter().any(|e| e.token == 7 && e.writable));
        poller.delete(server.as_raw_fd()).unwrap();
    }

    #[test]
    fn wake_pipe_crosses_threads() {
        let poller = Poller::new().unwrap();
        let pipe = std::sync::Arc::new(WakePipe::new().unwrap());
        poller.add(pipe.read_fd(), 1, true, false).unwrap();

        let remote = pipe.clone();
        let t = std::thread::spawn(move || remote.wake());
        let mut events = Vec::new();
        poller.wait(&mut events, Some(Duration::from_secs(2))).unwrap();
        t.join().unwrap();
        assert!(events.iter().any(|e| e.token == 1 && e.readable));
        pipe.drain();
        // Drained: the readiness condition is gone.
        poller.wait(&mut events, Some(Duration::from_millis(10))).unwrap();
        assert!(events.is_empty());
    }

    #[test]
    fn timer_wheel_fires_once_per_entry_and_tracks_depth() {
        let t0 = Instant::now();
        let mut wheel = TimerWheel::new(8, Duration::from_millis(10), t0);
        wheel.schedule(t0, t0 + Duration::from_millis(25), 1, 0);
        wheel.schedule(t0, t0 + Duration::from_millis(5), 2, 9);
        assert_eq!(wheel.depth(), 2);

        let mut fired = Vec::new();
        wheel.advance(t0 + Duration::from_millis(11), &mut fired);
        assert_eq!(fired, vec![(2, 9)]);
        assert_eq!(wheel.depth(), 1);

        fired.clear();
        wheel.advance(t0 + Duration::from_millis(60), &mut fired);
        assert_eq!(fired, vec![(1, 0)]);
        assert_eq!(wheel.depth(), 0);
    }

    #[test]
    fn timer_wheel_past_due_deadline_fires_on_next_advance() {
        // A deadline that already lapsed (or lands inside the current
        // tick) must fire on the very next advance, not a full slot
        // later — the old floor-plus-one placement pushed it one 100 ms
        // slot out and read/drain deadlines fired up to two ticks late.
        let t0 = Instant::now();
        let mut wheel = TimerWheel::new(8, Duration::from_millis(10), t0);
        let now = t0 + Duration::from_millis(2);
        wheel.schedule(now, t0, 3, 4); // lapsed 2 ms ago
        wheel.schedule(now, now, 5, 6); // due exactly now
        assert_eq!(wheel.depth(), 2);

        let mut fired = Vec::new();
        wheel.advance(t0 + Duration::from_millis(11), &mut fired);
        fired.sort_unstable();
        assert_eq!(fired, vec![(3, 4), (5, 6)]);
        assert_eq!(wheel.depth(), 0);
    }

    #[test]
    fn timer_wheel_exact_tick_multiple_is_not_a_tick_late() {
        // ceil(20 ms / 10 ms) = 2 slots: due at the second advance
        // step, where the old floor+1 arithmetic parked it at 3 and it
        // fired a full tick after its deadline.
        let t0 = Instant::now();
        let mut wheel = TimerWheel::new(8, Duration::from_millis(10), t0);
        wheel.schedule(t0, t0 + Duration::from_millis(20), 7, 0);

        let mut fired = Vec::new();
        wheel.advance(t0 + Duration::from_millis(11), &mut fired);
        assert!(fired.is_empty(), "not due at tick 1");
        wheel.advance(t0 + Duration::from_millis(21), &mut fired);
        assert_eq!(fired, vec![(7, 0)]);
    }

    #[test]
    fn timer_wheel_horizon_overflow_still_fires() {
        // A deadline past the wheel span lands in the furthest slot;
        // the loop re-checks real deadlines on fire, so early firing
        // is correct as long as the entry is never lost.
        let t0 = Instant::now();
        let mut wheel = TimerWheel::new(4, Duration::from_millis(10), t0);
        wheel.schedule(t0, t0 + Duration::from_secs(3600), 5, 1);
        let mut fired = Vec::new();
        wheel.advance(t0 + Duration::from_millis(200), &mut fired);
        assert_eq!(fired, vec![(5, 1)]);
    }

    #[test]
    fn nofile_limit_is_monotone() {
        let before = raise_nofile_limit(0);
        assert!(before > 0);
        let after = raise_nofile_limit(before);
        assert!(after >= before);
    }
}
