//! Network serving subsystem: the process boundary in front of the
//! [`crate::coordinator`].
//!
//! ```text
//!  loadgen/client ──TCP──► acceptor ──► per-conn reader ─submit─► model route
//!      ▲                                  (bounded pool)              │ least-loaded pool pick
//!      │                               per-conn writer ◄──response───┤
//!      └───────────── frames (wire.rs, v3) ─────┘                    ▼
//!                                               per-(backend × model) worker pools
//!                                                        (N replicas each)
//!
//!  SwapModel ──► ModelRegistry: catalog (versioned EMLP + SPx blobs)
//!                     │ per-slot generation counters
//!                     ▼
//!        Swappable{Cpu,Fpga}Backend refresh from their slot between batches
//! ```
//!
//! * [`wire`] — the versioned length-prefixed binary protocol, v2 with
//!   model-name routing and `ListModels` (`docs/wire-protocol.md` is
//!   the spec; v1 frames still accepted);
//! * [`server`] — `TcpListener` acceptor + bounded connection pool
//!   bridging frames onto the coordinator's batching queues;
//!   [`Server::serve`] assembles the replicated multi-model engine
//!   from an [`EngineConfig`];
//! * [`registry`] — catalog of versioned models + independently
//!   hot-swappable serving slots with EMLP+SPx persistence,
//!   slot-following backends, and derived VSQ int8/int4 artifacts with
//!   a per-slot precision preference ([`wire::Precision`],
//!   docs/quantization-modes.md);
//! * [`pipeline_backend`] — the stage-pipelined execution backend (one
//!   thread per MLP layer, `depth` micro-batches in flight, bitwise
//!   identical to the monolithic forward — docs/pipelined-engine.md);
//! * [`client`] — blocking model-aware client and the open/closed-loop
//!   load generator behind `edgemlp loadgen` and `BENCH_serving.json`.
//!
//! Observability rides on top of this subsystem: the server threads a
//! [`crate::obs::TraceRecorder`] through the coordinator and the
//! pipeline stages (exported by the v4 `DumpTrace` opcode), renders
//! Prometheus text via `StatsV2` or the `--metrics-addr` sidecar, and
//! appends modeled energy figures to `Stats` — see [`crate::obs`] and
//! `docs/observability.md`.

pub mod client;
pub mod pipeline_backend;
pub mod registry;
pub mod server;
pub mod wire;

pub use client::{
    run_loadgen, run_slo_sweep, BatchReply, Client, InferReply, LoadGenConfig, LoadGenReport,
    ModelReport, RetryPolicy, RetryingClient, SloPoint,
};
pub use pipeline_backend::{
    pipeline_cpu_factory, pipeline_cpu_factory_traced, pipeline_fpga_factory,
    pipeline_fpga_factory_traced, PipelineCpuBackend, PipelineFpgaBackend,
    SwappablePipelineCpuBackend, SwappablePipelineFpgaBackend,
};
pub use registry::{
    swappable_cpu_factory, swappable_fpga_factory, swappable_vsq_factory, ModelRegistry,
    ModelSlot, ModelVersion, SwapError,
};
pub use server::{BackendKind, EngineConfig, ServeConfig, Server};
pub use wire::{
    Frame, HealthReport, ModelInfo, Opcode, PoolHealth, Precision, Priority, Qos, Status,
    BACKEND_ANY,
};
