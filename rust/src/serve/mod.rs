//! Network serving subsystem: the process boundary in front of the
//! [`crate::coordinator`].
//!
//! ```text
//!  loadgen/client ──TCP──► acceptor ──► per-conn reader ─submit─► coordinator queues
//!      ▲                                  (bounded pool)              │ batcher
//!      │                               per-conn writer ◄──response───┘
//!      └───────────── frames (wire.rs) ────────┘
//!
//!  SwapModel ──► ModelRegistry (versioned EMLP + SPx blobs)
//!                     │ generation counter
//!                     ▼
//!        Swappable{Cpu,Fpga}Backend refresh between batches
//! ```
//!
//! * [`wire`] — the versioned length-prefixed binary protocol
//!   (`docs/wire-protocol.md` is the spec);
//! * [`server`] — `TcpListener` acceptor + bounded connection pool
//!   bridging frames onto the coordinator's batching queues;
//! * [`registry`] — hot-swappable versioned model store with EMLP+SPx
//!   persistence and registry-following backends;
//! * [`client`] — blocking client and the open/closed-loop load
//!   generator behind `edgemlp loadgen` and `BENCH_serving.json`.

pub mod client;
pub mod registry;
pub mod server;
pub mod wire;

pub use client::{run_loadgen, BatchReply, Client, InferReply, LoadGenConfig, LoadGenReport};
pub use registry::{
    swappable_cpu_factory, swappable_fpga_factory, ModelRegistry, ModelVersion, SwapError,
};
pub use server::{ServeConfig, Server};
pub use wire::{Frame, Opcode, Status, BACKEND_ANY};
