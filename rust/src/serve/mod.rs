//! Network serving subsystem: the process boundary in front of the
//! [`crate::coordinator`].
//!
//! ```text
//!  loadgen/client ──TCP──► readiness event loop (epoll/kqueue, 1 thread)
//!      ▲                     │ nonblocking accept + incremental decode
//!      │                     ├─submit─► model route ──► least-loaded pool pick
//!      │                     ▼                                        │
//!      └── frames ◄── ordered writeback ◄── completion wakeups ◄──────┤
//!         (wire.rs, v4)                                               ▼
//!                                               per-(backend × model) worker pools
//!                                                        (N replicas each)
//!
//!  SwapModel ──► ModelRegistry: catalog (versioned EMLP + SPx blobs)
//!                     │ per-slot generation counters
//!                     ▼
//!        Swappable{Cpu,Fpga}Backend refresh from their slot between batches
//! ```
//!
//! * [`wire`] — the versioned length-prefixed binary protocol, v2 with
//!   model-name routing and `ListModels` (`docs/wire-protocol.md` is
//!   the spec; v1 frames still accepted);
//! * [`server`] — the single-threaded readiness event loop bridging
//!   frames onto the coordinator's batching queues (c10k-class:
//!   thread count is O(pools), not O(connections) —
//!   `docs/async-net.md`); [`Server::serve`] assembles the replicated
//!   multi-model engine from an [`EngineConfig`];
//! * [`poll`] — the std-only epoll/kqueue readiness abstraction
//!   ([`poll::Poller`]), wakeup pipe, and coarse timer wheel;
//! * [`conn`] — the per-connection state machine: incremental frame
//!   reassembly, ordered writeback, careful-close draining;
//! * [`registry`] — catalog of versioned models + independently
//!   hot-swappable serving slots with EMLP+SPx persistence,
//!   slot-following backends, and derived VSQ int8/int4 artifacts with
//!   a per-slot precision preference ([`wire::Precision`],
//!   docs/quantization-modes.md);
//! * [`pipeline_backend`] — the stage-pipelined execution backend (one
//!   thread per MLP layer, `depth` micro-batches in flight, bitwise
//!   identical to the monolithic forward — docs/pipelined-engine.md);
//! * [`client`] — blocking model-aware client and the open/closed-loop
//!   load generator behind `edgemlp loadgen` and `BENCH_serving.json`.
//!
//! Observability rides on top of this subsystem: the server threads a
//! [`crate::obs::TraceRecorder`] through the coordinator and the
//! pipeline stages (exported by the v4 `DumpTrace` opcode), renders
//! Prometheus text via `StatsV2` or the `--metrics-addr` sidecar, and
//! appends modeled energy figures to `Stats` — see [`crate::obs`] and
//! `docs/observability.md`.

pub mod client;
pub mod conn;
pub mod pipeline_backend;
pub mod poll;
pub mod registry;
pub mod server;
pub mod wire;

pub use client::{
    run_loadgen, run_reconnect_storm, run_slo_sweep, BatchReply, Client, InferReply,
    LoadGenConfig, LoadGenReport, ModelReport, RetryPolicy, RetryingClient, SloPoint, StormReport,
};
pub use pipeline_backend::{
    pipeline_cpu_factory, pipeline_cpu_factory_traced, pipeline_fpga_factory,
    pipeline_fpga_factory_traced, PipelineCpuBackend, PipelineFpgaBackend,
    SwappablePipelineCpuBackend, SwappablePipelineFpgaBackend,
};
pub use registry::{
    swappable_cpu_factory, swappable_fpga_factory, swappable_vsq_factory, ModelRegistry,
    ModelSlot, ModelVersion, SwapError,
};
pub use poll::raise_nofile_limit;
pub use server::{BackendKind, EngineConfig, ServeConfig, Server};
pub use wire::{
    AutoscaleHealth, Frame, HealthReport, LoopGauges, ModelInfo, Opcode, PoolHealth, Precision,
    Priority, Qos, Status, BACKEND_ANY,
};
