//! Blocking wire-protocol client plus the load generator the serving
//! benchmark and `edgemlp loadgen` drive.
//!
//! The client speaks protocol v2: every inference can name a served
//! model (the empty string routes to the server's default). It supports
//! both call-and-wait methods (`infer`, `stats`, `swap_model`,
//! `list_models`) and a pipelined pair (`send_infer` / `recv_infer`)
//! that keeps a window of requests in flight on one connection — the
//! open-loop load generator uses the latter so the server's dynamic
//! batcher actually sees batches.
//!
//! The load generator spreads its connections across the configured
//! model names (multi-model traffic from one invocation), optionally
//! discards a warm-up prefix from the latency report, and renders a
//! per-model percentile table.

use super::wire::{self, Frame, ModelInfo, Opcode, Status, BACKEND_ANY, DEFAULT_MAX_PAYLOAD};
use crate::util::rng::Pcg32;
use anyhow::{bail, Context, Result};
use std::collections::{BTreeMap, VecDeque};
use std::io::{BufReader, BufWriter, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

/// Outcome of one inference request, load-shed and failure modes
/// surfaced as data rather than transport errors.
#[derive(Debug, Clone, PartialEq)]
pub enum InferReply {
    /// The model's output vector.
    Output(Vec<f32>),
    /// Request shed under backpressure (retry later).
    Shed(String),
    /// Any other error status.
    Failed { status: Status, message: String },
}

/// Outcome of one `InferBatch` request.
#[derive(Debug, Clone, PartialEq)]
pub enum BatchReply {
    Outputs(Vec<Vec<f32>>),
    Shed(String),
    Failed { status: Status, message: String },
}

/// A blocking protocol client over one TCP connection.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    next_id: u64,
}

impl Client {
    pub fn connect(addr: impl ToSocketAddrs) -> Result<Client> {
        let stream = TcpStream::connect(addr).context("connect")?;
        stream.set_nodelay(true).ok();
        let write_half = stream.try_clone().context("clone stream")?;
        Ok(Client {
            reader: BufReader::new(stream),
            writer: BufWriter::new(write_half),
            next_id: 0,
        })
    }

    fn send(&mut self, opcode: Opcode, payload: Vec<u8>) -> Result<u64> {
        let id = self.next_id;
        self.next_id += 1;
        wire::write_frame(&mut self.writer, &Frame::ok(opcode, id, payload))?;
        self.writer.flush()?;
        Ok(id)
    }

    fn recv(&mut self) -> Result<Frame> {
        wire::read_frame(&mut self.reader, DEFAULT_MAX_PAYLOAD)
            .map_err(|e| anyhow::anyhow!("read response: {e}"))
    }

    /// Liveness probe; round-trips an opaque payload.
    pub fn ping(&mut self) -> Result<Duration> {
        let t0 = Instant::now();
        let id = self.send(Opcode::Ping, b"ping".to_vec())?;
        let resp = self.recv()?;
        if resp.request_id != id || resp.status != Status::Ok || resp.payload != b"ping" {
            bail!("bad ping response: {resp:?}");
        }
        Ok(t0.elapsed())
    }

    /// One inference round-trip against the server's default model on
    /// `backend` ([`BACKEND_ANY`] lets the server pick the least-loaded
    /// pool).
    pub fn infer(&mut self, backend: u32, x: &[f32]) -> Result<InferReply> {
        self.infer_model(backend, "", x)
    }

    /// One inference round-trip against a named model (the empty name
    /// is the server's default).
    pub fn infer_model(&mut self, backend: u32, model: &str, x: &[f32]) -> Result<InferReply> {
        let id = self.send_infer_model(backend, model, x)?;
        let (got, reply) = Self::parse_infer(self.recv()?)?;
        if got != id {
            bail!("response id {got} for request {id}");
        }
        Ok(reply)
    }

    /// Send an inference without waiting; pair with
    /// [`Client::recv_infer`]. Replies arrive in send order.
    pub fn send_infer(&mut self, backend: u32, x: &[f32]) -> Result<u64> {
        self.send_infer_model(backend, "", x)
    }

    /// Pipelined send against a named model.
    pub fn send_infer_model(&mut self, backend: u32, model: &str, x: &[f32]) -> Result<u64> {
        let payload =
            wire::encode_infer(backend, model, x).map_err(|e| anyhow::anyhow!(e))?;
        self.send(Opcode::Infer, payload)
    }

    /// Receive the next pipelined inference reply.
    pub fn recv_infer(&mut self) -> Result<(u64, InferReply)> {
        let frame = self.recv()?;
        Self::parse_infer(frame)
    }

    fn parse_infer(frame: Frame) -> Result<(u64, InferReply)> {
        let id = frame.request_id;
        let reply = match frame.status {
            Status::Ok => InferReply::Output(
                wire::decode_outputs(&frame.payload).map_err(|e| anyhow::anyhow!(e))?,
            ),
            Status::Backpressure => InferReply::Shed(frame.message()),
            status => InferReply::Failed { status, message: frame.message() },
        };
        Ok((id, reply))
    }

    /// One batched inference round-trip against the default model.
    pub fn infer_batch(&mut self, backend: u32, samples: &[Vec<f32>]) -> Result<BatchReply> {
        self.infer_batch_model(backend, "", samples)
    }

    /// One batched inference round-trip against a named model.
    pub fn infer_batch_model(
        &mut self,
        backend: u32,
        model: &str,
        samples: &[Vec<f32>],
    ) -> Result<BatchReply> {
        let payload =
            wire::encode_infer_batch(backend, model, samples).map_err(|e| anyhow::anyhow!(e))?;
        let id = self.send(Opcode::InferBatch, payload)?;
        let resp = self.recv()?;
        if resp.request_id != id {
            bail!("response id {} for request {id}", resp.request_id);
        }
        Ok(match resp.status {
            Status::Ok => BatchReply::Outputs(
                wire::decode_batch_outputs(&resp.payload).map_err(|e| anyhow::anyhow!(e))?,
            ),
            Status::Backpressure => BatchReply::Shed(resp.message()),
            status => BatchReply::Failed { status, message: resp.message() },
        })
    }

    /// Metrics snapshot (text, includes latency percentiles and the
    /// served models).
    pub fn stats(&mut self) -> Result<String> {
        let id = self.send(Opcode::Stats, Vec::new())?;
        let resp = self.recv()?;
        if resp.request_id != id || resp.status != Status::Ok {
            bail!("stats failed: {} {}", resp.status, resp.message());
        }
        Ok(resp.message())
    }

    /// Enumerate the served models (slot, active version, dims,
    /// generation).
    pub fn list_models(&mut self) -> Result<Vec<ModelInfo>> {
        let id = self.send(Opcode::ListModels, Vec::new())?;
        let resp = self.recv()?;
        if resp.request_id != id {
            bail!("response id {} for request {id}", resp.request_id);
        }
        if resp.status != Status::Ok {
            bail!("list models failed: {} {}", resp.status, resp.message());
        }
        wire::decode_model_list(&resp.payload).map_err(|e| anyhow::anyhow!(e))
    }

    /// Activate registered model `name` into the server's default slot
    /// (v1 semantics); returns the server's confirmation line.
    pub fn swap_model(&mut self, name: &str) -> Result<String> {
        self.swap_model_into("", name)
    }

    /// Activate registered model `source` into serving slot `slot` (the
    /// empty slot name targets the default slot).
    pub fn swap_model_into(&mut self, slot: &str, source: &str) -> Result<String> {
        let payload = wire::encode_swap(slot, source).map_err(|e| anyhow::anyhow!(e))?;
        let id = self.send(Opcode::SwapModel, payload)?;
        let resp = self.recv()?;
        if resp.request_id != id {
            bail!("response id {} for request {id}", resp.request_id);
        }
        if resp.status != Status::Ok {
            bail!("swap to '{source}' failed: {} — {}", resp.status, resp.message());
        }
        Ok(resp.message())
    }
}

// ---------------------------------------------------------------------------
// Load generator.
// ---------------------------------------------------------------------------

/// Load-generator knobs.
#[derive(Debug, Clone)]
pub struct LoadGenConfig {
    /// Total requests across all connections.
    pub requests: usize,
    /// Concurrent connections (one thread each).
    pub connections: usize,
    /// Backend index, or [`BACKEND_ANY`].
    pub backend: u32,
    /// Model names to drive; connections are spread round-robin across
    /// them. Empty = the server's default model only.
    pub models: Vec<String>,
    /// Input dimension of the served model(s).
    pub dim: usize,
    /// Offered load in requests/s across all connections; 0 = closed
    /// loop (each connection sends as fast as replies return).
    pub rate_rps: f64,
    /// Samples per request: 1 = `Infer` frames, >1 = `InferBatch`.
    pub batch: usize,
    /// Outstanding requests per connection (pipelining window; only
    /// meaningful for `batch == 1`).
    pub pipeline: usize,
    /// Ramp-up requests to exclude from the latency report (spread
    /// across connections; they still count as sent/ok).
    pub warmup: usize,
    pub seed: u64,
}

impl Default for LoadGenConfig {
    fn default() -> Self {
        LoadGenConfig {
            requests: 10_000,
            connections: 8,
            backend: BACKEND_ANY,
            models: Vec::new(),
            dim: 784,
            rate_rps: 0.0,
            batch: 1,
            pipeline: 1,
            warmup: 0,
            seed: 7,
        }
    }
}

/// Per-model slice of a load-generator run.
#[derive(Debug, Default, Clone)]
pub struct ModelReport {
    pub sent: usize,
    pub ok: usize,
    pub shed: usize,
    pub errors: usize,
    /// OK requests excluded from `latencies` as warm-up.
    pub warmup_excluded: usize,
    /// Client-observed seconds, send → reply, warm-up excluded.
    pub latencies: Vec<f64>,
}

impl ModelReport {
    fn merge(&mut self, other: &ModelReport) {
        self.sent += other.sent;
        self.ok += other.ok;
        self.shed += other.shed;
        self.errors += other.errors;
        self.warmup_excluded += other.warmup_excluded;
        self.latencies.extend_from_slice(&other.latencies);
    }
}

/// Aggregated result of one load-generator run. `latencies` are
/// client-observed seconds, send → reply, with the warm-up prefix
/// excluded; `per_model` breaks the same numbers down by model name.
#[derive(Debug, Default, Clone)]
pub struct LoadGenReport {
    pub sent: usize,
    pub ok: usize,
    pub shed: usize,
    pub errors: usize,
    /// Requests answered OK but excluded from `latencies` as warm-up.
    pub warmup_excluded: usize,
    pub latencies: Vec<f64>,
    pub per_model: BTreeMap<String, ModelReport>,
    pub elapsed_s: f64,
}

impl LoadGenReport {
    /// Completed (answered) requests per second.
    pub fn throughput_rps(&self) -> f64 {
        if self.elapsed_s > 0.0 {
            self.ok as f64 / self.elapsed_s
        } else {
            0.0
        }
    }

    pub fn p50_s(&self) -> f64 {
        crate::util::percentile(&self.latencies, 50.0)
    }

    pub fn p99_s(&self) -> f64 {
        crate::util::percentile(&self.latencies, 99.0)
    }

    /// The aggregate summary line plus a per-model percentile table.
    pub fn render(&self) -> String {
        use crate::bench_harness::{fmt_time, Table};
        use crate::util::percentile;
        let mut out = format!(
            "sent {} | ok {} | shed {} | errors {} | {:.0} req/s | p50 {} | p99 {}",
            self.sent,
            self.ok,
            self.shed,
            self.errors,
            self.throughput_rps(),
            fmt_time(self.p50_s()),
            fmt_time(self.p99_s()),
        );
        if self.warmup_excluded > 0 {
            out.push_str(&format!(" | warmup excluded {}", self.warmup_excluded));
        }
        out.push('\n');
        let mut table =
            Table::new(&["model", "sent", "ok", "shed", "err", "p50", "p95", "p99", "p99.9"]);
        for (name, m) in &self.per_model {
            let display = if name.is_empty() { "(default)" } else { name };
            table.row(&[
                display.to_string(),
                m.sent.to_string(),
                m.ok.to_string(),
                m.shed.to_string(),
                m.errors.to_string(),
                fmt_time(percentile(&m.latencies, 50.0)),
                fmt_time(percentile(&m.latencies, 95.0)),
                fmt_time(percentile(&m.latencies, 99.0)),
                fmt_time(percentile(&m.latencies, 99.9)),
            ]);
        }
        out.push_str(&table.render());
        out
    }

    fn merge(&mut self, model: &str, other: ModelReport) {
        self.sent += other.sent;
        self.ok += other.ok;
        self.shed += other.shed;
        self.errors += other.errors;
        self.warmup_excluded += other.warmup_excluded;
        self.latencies.extend_from_slice(&other.latencies);
        self.per_model.entry(model.to_string()).or_default().merge(&other);
    }
}

/// Drive `config.requests` inferences at `addr` and aggregate the
/// outcome. Request payloads are uniform random vectors in `[0, 1)`.
pub fn run_loadgen(addr: std::net::SocketAddr, config: LoadGenConfig) -> Result<LoadGenReport> {
    anyhow::ensure!(config.connections > 0, "need at least one connection");
    anyhow::ensure!(config.batch > 0, "batch must be positive");
    let models = if config.models.is_empty() {
        vec![String::new()]
    } else {
        config.models.clone()
    };
    let per_conn = config.requests.div_ceil(config.connections);
    let warmup_per_conn = config.warmup.div_ceil(config.connections);
    let t0 = Instant::now();
    let mut threads = Vec::new();
    for c in 0..config.connections {
        let remaining = config.requests.saturating_sub(c * per_conn);
        let quota = per_conn.min(remaining);
        if quota == 0 {
            break;
        }
        let config = config.clone();
        let model = models[c % models.len()].clone();
        threads.push(std::thread::spawn(move || -> Result<(String, ModelReport)> {
            let seed = config.seed ^ (c as u64).wrapping_mul(0x9e37);
            let report =
                connection_worker(addr, &config, &model, quota, warmup_per_conn, seed)?;
            Ok((model, report))
        }));
    }
    let mut report = LoadGenReport::default();
    for t in threads {
        let (model, conn_report) = t.join().expect("loadgen thread panicked")?;
        report.merge(&model, conn_report);
    }
    report.elapsed_s = t0.elapsed().as_secs_f64();
    Ok(report)
}

fn connection_worker(
    addr: std::net::SocketAddr,
    config: &LoadGenConfig,
    model: &str,
    quota: usize,
    warmup: usize,
    seed: u64,
) -> Result<ModelReport> {
    let mut client = Client::connect(addr)?;
    let mut rng = Pcg32::new(seed);
    let mut report = ModelReport::default();
    // Completed samples so far — the first `warmup` are excluded from
    // the latency vectors.
    let mut completed = 0usize;
    let sample = |rng: &mut Pcg32| -> Vec<f32> {
        (0..config.dim).map(|_| rng.uniform() as f32).collect()
    };
    // Per-connection share of the offered rate, Poisson arrivals.
    let conn_rate = config.rate_rps / config.connections as f64;
    let t0 = Instant::now();
    let mut next_arrival = 0.0f64;
    let mut pace = |rng: &mut Pcg32| {
        if conn_rate > 0.0 {
            let u: f64 = rng.uniform().max(1e-12);
            next_arrival += -u.ln() / conn_rate;
            let wait = next_arrival - t0.elapsed().as_secs_f64();
            if wait > 0.0 {
                std::thread::sleep(Duration::from_secs_f64(wait));
            }
        }
    };

    if config.batch > 1 {
        let mut sent = 0usize;
        while sent < quota {
            let b = config.batch.min(quota - sent);
            let samples: Vec<Vec<f32>> = (0..b).map(|_| sample(&mut rng)).collect();
            pace(&mut rng);
            let t = Instant::now();
            match client.infer_batch_model(config.backend, model, &samples)? {
                BatchReply::Outputs(rows) => {
                    anyhow::ensure!(rows.len() == b, "batch reply size {} != {b}", rows.len());
                    report.ok += b;
                    if completed >= warmup {
                        report.latencies.push(t.elapsed().as_secs_f64());
                    } else {
                        // A batch straddling the warm-up boundary is
                        // excluded whole — its latency is one sample.
                        report.warmup_excluded += b;
                    }
                    completed += b;
                }
                BatchReply::Shed(_) => report.shed += b,
                BatchReply::Failed { .. } => report.errors += b,
            }
            sent += b;
            report.sent += b;
        }
        return Ok(report);
    }

    // Single-sample path with a pipelining window.
    let window = config.pipeline.max(1);
    let mut in_flight: VecDeque<(u64, Instant)> = VecDeque::with_capacity(window);
    let drain_one = |client: &mut Client,
                     in_flight: &mut VecDeque<(u64, Instant)>,
                     report: &mut ModelReport,
                     completed: &mut usize|
     -> Result<()> {
        let (id, sent_at) = in_flight.pop_front().expect("drain on empty window");
        let (got, reply) = client.recv_infer()?;
        anyhow::ensure!(got == id, "reply {got} out of order (expected {id})");
        match reply {
            InferReply::Output(_) => {
                report.ok += 1;
                if *completed >= warmup {
                    report.latencies.push(sent_at.elapsed().as_secs_f64());
                } else {
                    report.warmup_excluded += 1;
                }
                *completed += 1;
            }
            InferReply::Shed(_) => report.shed += 1,
            InferReply::Failed { .. } => report.errors += 1,
        }
        Ok(())
    };
    for _ in 0..quota {
        if in_flight.len() >= window {
            drain_one(&mut client, &mut in_flight, &mut report, &mut completed)?;
        }
        let x = sample(&mut rng);
        pace(&mut rng);
        let id = client.send_infer_model(config.backend, model, &x)?;
        in_flight.push_back((id, Instant::now()));
        report.sent += 1;
    }
    while !in_flight.is_empty() {
        drain_one(&mut client, &mut in_flight, &mut report, &mut completed)?;
    }
    Ok(report)
}
