//! Blocking wire-protocol client plus the load generator the serving
//! benchmark and `edgemlp loadgen` drive.
//!
//! The client speaks protocol v2: every inference can name a served
//! model (the empty string routes to the server's default). It supports
//! both call-and-wait methods (`infer`, `stats`, `swap_model`,
//! `list_models`) and a pipelined pair (`send_infer` / `recv_infer`)
//! that keeps a window of requests in flight on one connection — the
//! open-loop load generator uses the latter so the server's dynamic
//! batcher actually sees batches.
//!
//! The load generator spreads its connections across the configured
//! model names (multi-model traffic from one invocation), optionally
//! discards a warm-up prefix from the latency report, and renders a
//! per-model percentile table.

use super::wire::{
    self, Frame, HealthReport, ModelInfo, Opcode, Priority, Qos, Status, BACKEND_ANY,
    DEFAULT_MAX_PAYLOAD,
};
use crate::util::rng::Pcg32;
use anyhow::{bail, Context, Result};
use std::collections::{BTreeMap, VecDeque};
use std::io::{BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Outcome of one inference request, load-shed and failure modes
/// surfaced as data rather than transport errors.
#[derive(Debug, Clone, PartialEq)]
pub enum InferReply {
    /// The model's output vector.
    Output(Vec<f32>),
    /// Request shed under backpressure (retry later).
    Shed(String),
    /// Any other error status.
    Failed { status: Status, message: String },
}

/// Outcome of one `InferBatch` request.
#[derive(Debug, Clone, PartialEq)]
pub enum BatchReply {
    Outputs(Vec<Vec<f32>>),
    Shed(String),
    Failed { status: Status, message: String },
}

/// A blocking protocol client over one TCP connection.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    next_id: u64,
}

impl Client {
    pub fn connect(addr: impl ToSocketAddrs) -> Result<Client> {
        let stream = TcpStream::connect(addr).context("connect")?;
        stream.set_nodelay(true).ok();
        let write_half = stream.try_clone().context("clone stream")?;
        Ok(Client {
            reader: BufReader::new(stream),
            writer: BufWriter::new(write_half),
            next_id: 0,
        })
    }

    /// Cap how long any single read/write on this connection may block.
    /// The retrying client sets this to its per-attempt budget so a
    /// wedged server turns into a retryable transport error instead of
    /// an indefinite hang.
    pub fn set_io_timeout(&mut self, timeout: Option<Duration>) -> Result<()> {
        self.reader.get_ref().set_read_timeout(timeout).context("set read timeout")?;
        self.writer.get_ref().set_write_timeout(timeout).context("set write timeout")?;
        Ok(())
    }

    fn send(&mut self, opcode: Opcode, payload: Vec<u8>) -> Result<u64> {
        let id = self.next_id;
        self.next_id += 1;
        self.send_with_id(id, opcode, payload)?;
        Ok(id)
    }

    /// Write a frame under a caller-chosen request id. The retrying
    /// client reuses one id across attempts of the same logical request
    /// so duplicate submissions are observable server-side.
    fn send_with_id(&mut self, id: u64, opcode: Opcode, payload: Vec<u8>) -> Result<()> {
        wire::write_frame(&mut self.writer, &Frame::ok(opcode, id, payload))?;
        self.writer.flush()?;
        Ok(())
    }

    fn recv(&mut self) -> Result<Frame> {
        wire::read_frame(&mut self.reader, DEFAULT_MAX_PAYLOAD)
            .map_err(|e| anyhow::anyhow!("read response: {e}"))
    }

    /// Liveness probe; round-trips an opaque payload.
    pub fn ping(&mut self) -> Result<Duration> {
        let t0 = Instant::now();
        let id = self.send(Opcode::Ping, b"ping".to_vec())?;
        let resp = self.recv()?;
        if resp.request_id != id || resp.status != Status::Ok || resp.payload != b"ping" {
            bail!("bad ping response: {resp:?}");
        }
        Ok(t0.elapsed())
    }

    /// One inference round-trip against the server's default model on
    /// `backend` ([`BACKEND_ANY`] lets the server pick the least-loaded
    /// pool).
    pub fn infer(&mut self, backend: u32, x: &[f32]) -> Result<InferReply> {
        self.infer_model(backend, "", x)
    }

    /// One inference round-trip against a named model (the empty name
    /// is the server's default).
    pub fn infer_model(&mut self, backend: u32, model: &str, x: &[f32]) -> Result<InferReply> {
        let id = self.send_infer_model(backend, model, x)?;
        let (got, reply) = Self::parse_infer(self.recv()?)?;
        if got != id {
            bail!("response id {got} for request {id}");
        }
        Ok(reply)
    }

    /// One inference round-trip with explicit QoS (deadline budget +
    /// priority).
    pub fn infer_qos(
        &mut self,
        backend: u32,
        model: &str,
        qos: Qos,
        x: &[f32],
    ) -> Result<InferReply> {
        let id = self.send_infer_qos(backend, model, qos, x)?;
        let (got, reply) = Self::parse_infer(self.recv()?)?;
        if got != id {
            bail!("response id {got} for request {id}");
        }
        Ok(reply)
    }

    /// Send an inference without waiting; pair with
    /// [`Client::recv_infer`]. Replies arrive in send order.
    pub fn send_infer(&mut self, backend: u32, x: &[f32]) -> Result<u64> {
        self.send_infer_model(backend, "", x)
    }

    /// Pipelined send against a named model.
    pub fn send_infer_model(&mut self, backend: u32, model: &str, x: &[f32]) -> Result<u64> {
        self.send_infer_qos(backend, model, Qos::NONE, x)
    }

    /// Pipelined send with explicit QoS.
    pub fn send_infer_qos(
        &mut self,
        backend: u32,
        model: &str,
        qos: Qos,
        x: &[f32],
    ) -> Result<u64> {
        let payload =
            wire::encode_infer_qos(backend, model, qos, x).map_err(|e| anyhow::anyhow!(e))?;
        self.send(Opcode::Infer, payload)
    }

    /// Pipelined QoS send under a caller-chosen request id (see
    /// [`RetryingClient`]).
    pub fn send_infer_qos_id(
        &mut self,
        id: u64,
        backend: u32,
        model: &str,
        qos: Qos,
        x: &[f32],
    ) -> Result<()> {
        let payload =
            wire::encode_infer_qos(backend, model, qos, x).map_err(|e| anyhow::anyhow!(e))?;
        self.send_with_id(id, Opcode::Infer, payload)
    }

    /// Receive the next pipelined inference reply.
    pub fn recv_infer(&mut self) -> Result<(u64, InferReply)> {
        let frame = self.recv()?;
        Self::parse_infer(frame)
    }

    fn parse_infer(frame: Frame) -> Result<(u64, InferReply)> {
        let id = frame.request_id;
        let reply = match frame.status {
            Status::Ok => InferReply::Output(
                wire::decode_outputs(&frame.payload).map_err(|e| anyhow::anyhow!(e))?,
            ),
            Status::Backpressure => InferReply::Shed(frame.message()),
            status => InferReply::Failed { status, message: frame.message() },
        };
        Ok((id, reply))
    }

    /// One batched inference round-trip against the default model.
    pub fn infer_batch(&mut self, backend: u32, samples: &[Vec<f32>]) -> Result<BatchReply> {
        self.infer_batch_model(backend, "", samples)
    }

    /// One batched inference round-trip against a named model.
    pub fn infer_batch_model(
        &mut self,
        backend: u32,
        model: &str,
        samples: &[Vec<f32>],
    ) -> Result<BatchReply> {
        self.infer_batch_qos(backend, model, Qos::NONE, samples)
    }

    /// One batched inference round-trip with explicit QoS (one deadline
    /// and priority for the whole batch).
    pub fn infer_batch_qos(
        &mut self,
        backend: u32,
        model: &str,
        qos: Qos,
        samples: &[Vec<f32>],
    ) -> Result<BatchReply> {
        let payload = wire::encode_infer_batch_qos(backend, model, qos, samples)
            .map_err(|e| anyhow::anyhow!(e))?;
        let id = self.send(Opcode::InferBatch, payload)?;
        let resp = self.recv()?;
        if resp.request_id != id {
            bail!("response id {} for request {id}", resp.request_id);
        }
        Ok(match resp.status {
            Status::Ok => BatchReply::Outputs(
                wire::decode_batch_outputs(&resp.payload).map_err(|e| anyhow::anyhow!(e))?,
            ),
            Status::Backpressure => BatchReply::Shed(resp.message()),
            status => BatchReply::Failed { status, message: resp.message() },
        })
    }

    /// Metrics snapshot (text, includes latency percentiles and the
    /// served models).
    pub fn stats(&mut self) -> Result<String> {
        let id = self.send(Opcode::Stats, Vec::new())?;
        let resp = self.recv()?;
        if resp.request_id != id || resp.status != Status::Ok {
            bail!("stats failed: {} {}", resp.status, resp.message());
        }
        Ok(resp.message())
    }

    /// Prometheus text exposition over the wire (`StatsV2`, protocol
    /// v4) — byte-identical to the `/metrics` sidecar body, for
    /// environments where only the inference port is reachable.
    pub fn metrics_text(&mut self) -> Result<String> {
        let id = self.send(Opcode::StatsV2, Vec::new())?;
        let resp = self.recv()?;
        if resp.request_id != id {
            bail!("response id {} for request {id}", resp.request_id);
        }
        if resp.status != Status::Ok {
            bail!("metrics failed: {} {}", resp.status, resp.message());
        }
        Ok(resp.message())
    }

    /// Export the server's request-lifecycle trace ring as Chrome
    /// trace-event JSON (`DumpTrace`, protocol v4) — loadable in
    /// Perfetto / `chrome://tracing`.
    pub fn dump_trace(&mut self) -> Result<String> {
        let id = self.send(Opcode::DumpTrace, Vec::new())?;
        let resp = self.recv()?;
        if resp.request_id != id {
            bail!("response id {} for request {id}", resp.request_id);
        }
        if resp.status != Status::Ok {
            bail!("trace dump failed: {} {}", resp.status, resp.message());
        }
        Ok(resp.message())
    }

    /// Resilience counters: per-pool queue depths, shed/expired counts,
    /// degraded-mode state (protocol v3).
    pub fn health(&mut self) -> Result<HealthReport> {
        let id = self.send(Opcode::Health, Vec::new())?;
        let resp = self.recv()?;
        if resp.request_id != id {
            bail!("response id {} for request {id}", resp.request_id);
        }
        if resp.status != Status::Ok {
            bail!("health failed: {} {}", resp.status, resp.message());
        }
        wire::decode_health(&resp.payload).map_err(|e| anyhow::anyhow!(e))
    }

    /// [`Client::health`] plus the trailing v4 blocks: event-loop
    /// gauges and autoscaler state (`None` when the server predates
    /// either block).
    pub fn health_full(
        &mut self,
    ) -> Result<(HealthReport, Option<wire::LoopGauges>, Option<wire::AutoscaleHealth>)> {
        let id = self.send(Opcode::Health, Vec::new())?;
        let resp = self.recv()?;
        if resp.request_id != id {
            bail!("response id {} for request {id}", resp.request_id);
        }
        if resp.status != Status::Ok {
            bail!("health failed: {} {}", resp.status, resp.message());
        }
        wire::decode_health_full(&resp.payload).map_err(|e| anyhow::anyhow!(e))
    }

    /// Enumerate the served models (slot, active version, dims,
    /// generation).
    pub fn list_models(&mut self) -> Result<Vec<ModelInfo>> {
        let id = self.send(Opcode::ListModels, Vec::new())?;
        let resp = self.recv()?;
        if resp.request_id != id {
            bail!("response id {} for request {id}", resp.request_id);
        }
        if resp.status != Status::Ok {
            bail!("list models failed: {} {}", resp.status, resp.message());
        }
        wire::decode_model_list(&resp.payload).map_err(|e| anyhow::anyhow!(e))
    }

    /// Activate registered model `name` into the server's default slot
    /// (v1 semantics); returns the server's confirmation line.
    pub fn swap_model(&mut self, name: &str) -> Result<String> {
        self.swap_model_into("", name)
    }

    /// Activate registered model `source` into serving slot `slot` (the
    /// empty slot name targets the default slot).
    pub fn swap_model_into(&mut self, slot: &str, source: &str) -> Result<String> {
        self.swap_model_with_precision(slot, source, None)
    }

    /// Activate registered model `source` into serving slot `slot`, and
    /// optionally pin the slot's preferred serving precision (protocol
    /// v4 — older servers reject the precision byte with `BadRequest`,
    /// so callers talking to pre-v4 servers should pass `None`).
    pub fn swap_model_with_precision(
        &mut self,
        slot: &str,
        source: &str,
        precision: Option<wire::Precision>,
    ) -> Result<String> {
        let payload =
            wire::encode_swap_precision(slot, source, precision).map_err(|e| anyhow::anyhow!(e))?;
        let id = self.send(Opcode::SwapModel, payload)?;
        let resp = self.recv()?;
        if resp.request_id != id {
            bail!("response id {} for request {id}", resp.request_id);
        }
        if resp.status != Status::Ok {
            bail!("swap to '{source}' failed: {} — {}", resp.status, resp.message());
        }
        Ok(resp.message())
    }
}

// ---------------------------------------------------------------------------
// Retry policy.
// ---------------------------------------------------------------------------

/// Bounded retry with exponential backoff and multiplicative jitter.
///
/// What retries and what does not: `Busy` (connection limit), shed
/// (`Backpressure`), admission-`Expired`, connect failures, and
/// per-attempt timeouts are transient — load-dependent — so they retry.
/// `BadRequest`, `UnknownModel`, and other semantic failures would fail
/// identically on every attempt and are returned immediately.
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    /// Total tries including the first (1 = no retry).
    pub max_attempts: u32,
    /// Backoff before the first retry; doubles per further retry.
    pub base_backoff: Duration,
    /// Backoff growth cap.
    pub max_backoff: Duration,
    /// Fraction of each backoff randomized away, in `[0, 1]`: the delay
    /// is uniform in `[backoff × (1 − jitter), backoff]`. Keeps a
    /// synchronized herd of shed clients from re-arriving in lockstep.
    pub jitter: f64,
    /// Per-attempt I/O budget (connect + round-trip). An attempt
    /// overrunning it is abandoned and its connection dropped.
    pub attempt_timeout: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 4,
            base_backoff: Duration::from_millis(10),
            max_backoff: Duration::from_secs(1),
            jitter: 0.5,
            attempt_timeout: Duration::from_secs(2),
        }
    }
}

impl RetryPolicy {
    /// The delay before retry number `retry` (0-based: the delay after
    /// the first failed attempt is `backoff_for(0, ..)`). Deterministic
    /// given the RNG state — unit tests drive it with a seeded
    /// [`Pcg32`].
    pub fn backoff_for(&self, retry: u32, rng: &mut Pcg32) -> Duration {
        let exp = self.base_backoff.as_secs_f64() * 2f64.powi(retry.min(30) as i32);
        let capped = exp.min(self.max_backoff.as_secs_f64());
        let jitter = self.jitter.clamp(0.0, 1.0);
        Duration::from_secs_f64(capped * (1.0 - jitter * rng.uniform()))
    }
}

/// Whether a reply is worth retrying (load-transient) or final.
fn retryable_status(status: Status) -> bool {
    matches!(status, Status::Busy | Status::Backpressure | Status::Expired)
}

/// A client wrapper applying a [`RetryPolicy`] to single inferences.
///
/// At-most-once by construction: every logical request keeps ONE wire
/// request id across all its attempts, and whenever an attempt is
/// abandoned (timeout, transport error, `Busy`) the whole connection is
/// dropped — a late reply to an abandoned attempt can never be consumed,
/// so the caller sees at most one answer per logical request.
pub struct RetryingClient {
    addr: SocketAddr,
    policy: RetryPolicy,
    conn: Option<Client>,
    rng: Pcg32,
    next_id: u64,
    /// Wire attempts made over this client's lifetime (first tries +
    /// retries). Retries may land on fresh connections and even a
    /// different server replica, so the server cannot correlate them —
    /// the client is the only place retry pressure is countable
    /// (`docs/observability.md`).
    attempts_total: u64,
    /// The subset of `attempts_total` that re-tried an earlier attempt
    /// of the same logical request.
    retries_total: u64,
}

impl RetryingClient {
    /// Lazily connecting — the first attempt dials.
    pub fn new(addr: SocketAddr, policy: RetryPolicy, seed: u64) -> RetryingClient {
        RetryingClient {
            addr,
            policy,
            conn: None,
            rng: Pcg32::new(seed),
            next_id: 0,
            attempts_total: 0,
            retries_total: 0,
        }
    }

    /// Total wire attempts this client has made (first tries + retries).
    pub fn attempts_total(&self) -> u64 {
        self.attempts_total
    }

    /// Wire attempts that were retries of an earlier logical request.
    pub fn retries_total(&self) -> u64 {
        self.retries_total
    }

    /// One logical inference: up to `max_attempts` tries, backoff with
    /// jitter between them. Returns the final reply and how many
    /// attempts it took; `Err` only when every attempt died on
    /// transport (the last transport error).
    pub fn infer_qos(
        &mut self,
        backend: u32,
        model: &str,
        qos: Qos,
        x: &[f32],
    ) -> Result<(InferReply, u32)> {
        let id = self.next_id;
        self.next_id += 1;
        let max_attempts = self.policy.max_attempts.max(1);
        let mut attempts = 0u32;
        loop {
            attempts += 1;
            self.attempts_total += 1;
            if attempts > 1 {
                self.retries_total += 1;
            }
            let outcome = self.attempt(id, backend, model, qos, x);
            match outcome {
                Ok(reply) => {
                    let retry = match &reply {
                        InferReply::Shed(_) => true,
                        InferReply::Failed { status, .. } => {
                            if *status == Status::Busy {
                                // Busy connections are closed server-side;
                                // do not reuse ours.
                                self.conn = None;
                            }
                            retryable_status(*status)
                        }
                        InferReply::Output(_) => false,
                    };
                    if !retry || attempts >= max_attempts {
                        return Ok((reply, attempts));
                    }
                }
                Err(e) => {
                    if attempts >= max_attempts {
                        return Err(e.context(format!("after {attempts} attempts")));
                    }
                }
            }
            std::thread::sleep(self.policy.backoff_for(attempts - 1, &mut self.rng));
        }
    }

    fn attempt(
        &mut self,
        id: u64,
        backend: u32,
        model: &str,
        qos: Qos,
        x: &[f32],
    ) -> Result<InferReply> {
        if self.conn.is_none() {
            let mut c = Client::connect(self.addr)?;
            c.set_io_timeout(Some(self.policy.attempt_timeout))?;
            self.conn = Some(c);
        }
        let conn = self.conn.as_mut().expect("connection just ensured");
        let result = (|| {
            conn.send_infer_qos_id(id, backend, model, qos, x)?;
            let (got, reply) = conn.recv_infer()?;
            anyhow::ensure!(got == id, "reply id {got} for request {id}");
            Ok(reply)
        })();
        if result.is_err() {
            // Abandoned attempt: a reply may still be in flight for this
            // id. Dropping the connection guarantees it is never read.
            self.conn = None;
        }
        result
    }
}

// ---------------------------------------------------------------------------
// Load generator.
// ---------------------------------------------------------------------------

/// Load-generator knobs.
#[derive(Debug, Clone)]
pub struct LoadGenConfig {
    /// Total requests across all connections.
    pub requests: usize,
    /// Concurrent connections (one thread each).
    pub connections: usize,
    /// Backend index, or [`BACKEND_ANY`].
    pub backend: u32,
    /// Model names to drive; connections are spread round-robin across
    /// them. Empty = the server's default model only.
    pub models: Vec<String>,
    /// Input dimension of the served model(s).
    pub dim: usize,
    /// Offered load in requests/s across all connections; 0 = closed
    /// loop (each connection sends as fast as replies return).
    pub rate_rps: f64,
    /// Samples per request: 1 = `Infer` frames, >1 = `InferBatch`.
    pub batch: usize,
    /// Outstanding requests per connection (pipelining window; only
    /// meaningful for `batch == 1`).
    pub pipeline: usize,
    /// Ramp-up requests to exclude from the latency report (spread
    /// across connections; they still count as sent/ok).
    pub warmup: usize,
    pub seed: u64,
    /// Per-request deadline budget in µs; 0 = no deadline. With a
    /// deadline set the report additionally tracks `expired` counts and
    /// deadline attainment (the SLO scenarios).
    pub deadline_us: u64,
    /// Priority stamped on every request.
    pub priority: Priority,
    /// Extra connections that ping once and then sit idle for the whole
    /// run — the c10k scenario's background population. They occupy
    /// server connection slots and poller registrations but generate no
    /// traffic, so the active connections' latency measures the event
    /// loop's ability to ignore them. The server's `--read-timeout-s`
    /// must exceed the run duration or they get reaped mid-run.
    pub idle_conns: usize,
}

impl Default for LoadGenConfig {
    fn default() -> Self {
        LoadGenConfig {
            requests: 10_000,
            connections: 8,
            backend: BACKEND_ANY,
            models: Vec::new(),
            dim: 784,
            rate_rps: 0.0,
            batch: 1,
            pipeline: 1,
            warmup: 0,
            seed: 7,
            deadline_us: 0,
            priority: Priority::Normal,
            idle_conns: 0,
        }
    }
}

impl LoadGenConfig {
    fn qos(&self) -> Qos {
        Qos { deadline_us: self.deadline_us, priority: self.priority }
    }
}

/// Per-model slice of a load-generator run.
#[derive(Debug, Default, Clone)]
pub struct ModelReport {
    pub sent: usize,
    pub ok: usize,
    pub shed: usize,
    /// Requests answered `Status::Expired` (admission reject or
    /// in-queue expiry) — deliberate load shedding, not errors.
    pub expired: usize,
    pub errors: usize,
    /// OK requests excluded from `latencies` as warm-up.
    pub warmup_excluded: usize,
    /// OK requests whose client-observed latency met the configured
    /// deadline (only tracked when `deadline_us > 0`).
    pub deadline_met: usize,
    /// Client-observed seconds, send → reply, warm-up excluded.
    pub latencies: Vec<f64>,
}

impl ModelReport {
    fn merge(&mut self, other: &ModelReport) {
        self.sent += other.sent;
        self.ok += other.ok;
        self.shed += other.shed;
        self.expired += other.expired;
        self.errors += other.errors;
        self.warmup_excluded += other.warmup_excluded;
        self.deadline_met += other.deadline_met;
        self.latencies.extend_from_slice(&other.latencies);
    }
}

/// Aggregated result of one load-generator run. `latencies` are
/// client-observed seconds, send → reply, with the warm-up prefix
/// excluded; `per_model` breaks the same numbers down by model name.
#[derive(Debug, Default, Clone)]
pub struct LoadGenReport {
    pub sent: usize,
    pub ok: usize,
    pub shed: usize,
    /// Requests answered `Status::Expired` by admission control or
    /// in-queue expiry.
    pub expired: usize,
    pub errors: usize,
    /// Requests answered OK but excluded from `latencies` as warm-up.
    pub warmup_excluded: usize,
    /// OK requests that met the deadline (when one was configured).
    pub deadline_met: usize,
    /// The deadline the run was driven with (µs; 0 = none) — lets the
    /// report render attainment without re-asking the config.
    pub deadline_us: u64,
    /// Idle background connections successfully opened and held for the
    /// whole run (≤ `LoadGenConfig::idle_conns`; fewer when the client
    /// host's fd limit bites first).
    pub idle_held: usize,
    pub latencies: Vec<f64>,
    pub per_model: BTreeMap<String, ModelReport>,
    pub elapsed_s: f64,
}

impl LoadGenReport {
    /// Completed (answered) requests per second.
    pub fn throughput_rps(&self) -> f64 {
        if self.elapsed_s > 0.0 {
            self.ok as f64 / self.elapsed_s
        } else {
            0.0
        }
    }

    /// Fraction of accepted (OK) requests that met the deadline; `None`
    /// without a configured deadline or without any OK request.
    pub fn attainment(&self) -> Option<f64> {
        (self.deadline_us > 0 && self.ok > 0)
            .then(|| self.deadline_met as f64 / self.ok as f64)
    }

    /// Fraction of sent requests deliberately shed (backpressure +
    /// expiry) rather than served.
    pub fn shed_rate(&self) -> f64 {
        if self.sent > 0 {
            (self.shed + self.expired) as f64 / self.sent as f64
        } else {
            0.0
        }
    }

    pub fn p50_s(&self) -> f64 {
        crate::util::percentile(&self.latencies, 50.0)
    }

    pub fn p99_s(&self) -> f64 {
        crate::util::percentile(&self.latencies, 99.0)
    }

    /// The aggregate summary line plus a per-model percentile table.
    pub fn render(&self) -> String {
        use crate::bench_harness::{fmt_time, Table};
        use crate::util::percentile;
        let mut out = format!(
            "sent {} | ok {} | shed {} | expired {} | errors {} | {:.0} req/s | p50 {} | p99 {}",
            self.sent,
            self.ok,
            self.shed,
            self.expired,
            self.errors,
            self.throughput_rps(),
            fmt_time(self.p50_s()),
            fmt_time(self.p99_s()),
        );
        if let Some(att) = self.attainment() {
            out.push_str(&format!(
                " | attainment {:.1}% of {} ms deadline",
                att * 100.0,
                self.deadline_us as f64 / 1e3
            ));
        }
        if self.warmup_excluded > 0 {
            out.push_str(&format!(" | warmup excluded {}", self.warmup_excluded));
        }
        if self.idle_held > 0 {
            out.push_str(&format!(" | idle conns held {}", self.idle_held));
        }
        out.push('\n');
        let mut table = Table::new(&[
            "model", "sent", "ok", "shed", "expired", "err", "p50", "p95", "p99", "p99.9",
        ]);
        for (name, m) in &self.per_model {
            let display = if name.is_empty() { "(default)" } else { name };
            table.row(&[
                display.to_string(),
                m.sent.to_string(),
                m.ok.to_string(),
                m.shed.to_string(),
                m.expired.to_string(),
                m.errors.to_string(),
                fmt_time(percentile(&m.latencies, 50.0)),
                fmt_time(percentile(&m.latencies, 95.0)),
                fmt_time(percentile(&m.latencies, 99.0)),
                fmt_time(percentile(&m.latencies, 99.9)),
            ]);
        }
        out.push_str(&table.render());
        out
    }

    fn merge(&mut self, model: &str, other: ModelReport) {
        self.sent += other.sent;
        self.ok += other.ok;
        self.shed += other.shed;
        self.expired += other.expired;
        self.errors += other.errors;
        self.warmup_excluded += other.warmup_excluded;
        self.deadline_met += other.deadline_met;
        self.latencies.extend_from_slice(&other.latencies);
        self.per_model.entry(model.to_string()).or_default().merge(&other);
    }
}

/// Drive `config.requests` inferences at `addr` and aggregate the
/// outcome. Request payloads are uniform random vectors in `[0, 1)`.
pub fn run_loadgen(addr: std::net::SocketAddr, config: LoadGenConfig) -> Result<LoadGenReport> {
    anyhow::ensure!(config.connections > 0, "need at least one connection");
    anyhow::ensure!(config.batch > 0, "batch must be positive");
    let models = if config.models.is_empty() {
        vec![String::new()]
    } else {
        config.models.clone()
    };
    let per_conn = config.requests.div_ceil(config.connections);
    let warmup_per_conn = config.warmup.div_ceil(config.connections);
    // The idle population connects (and verifies liveness with one
    // ping) BEFORE the clock starts, so the active connections measure
    // a server already holding `idle_conns` registered sockets.
    let (idle_stop, idle_threads) = hold_idle_connections(addr, config.idle_conns);
    let t0 = Instant::now();
    let mut threads = Vec::new();
    for c in 0..config.connections {
        let remaining = config.requests.saturating_sub(c * per_conn);
        let quota = per_conn.min(remaining);
        if quota == 0 {
            break;
        }
        let config = config.clone();
        let model = models[c % models.len()].clone();
        threads.push(std::thread::spawn(move || -> Result<(String, ModelReport)> {
            let seed = config.seed ^ (c as u64).wrapping_mul(0x9e37);
            let report =
                connection_worker(addr, &config, &model, quota, warmup_per_conn, seed)?;
            Ok((model, report))
        }));
    }
    let mut report = LoadGenReport::default();
    report.deadline_us = config.deadline_us;
    for t in threads {
        let (model, conn_report) = t.join().expect("loadgen thread panicked")?;
        report.merge(&model, conn_report);
    }
    report.elapsed_s = t0.elapsed().as_secs_f64();
    idle_stop.store(true, Ordering::Relaxed);
    for t in idle_threads {
        report.idle_held += t.join().unwrap_or(0);
    }
    Ok(report)
}

/// Open `count` connections that each verify liveness with one ping and
/// then sit fully idle until the returned stop flag flips — the c10k
/// background population. Returns once every opener has finished
/// connecting, so the caller's clock starts against the full
/// population. Connect failures stop that opener early (client-side fd
/// limits); the openers hold whatever they managed to get.
fn hold_idle_connections(
    addr: std::net::SocketAddr,
    count: usize,
) -> (Arc<AtomicBool>, Vec<std::thread::JoinHandle<usize>>) {
    let stop = Arc::new(AtomicBool::new(false));
    if count == 0 {
        return (stop, Vec::new());
    }
    let openers = count.min(8);
    let per = count.div_ceil(openers);
    let (ready_tx, ready_rx) = std::sync::mpsc::channel::<()>();
    let mut threads = Vec::new();
    for o in 0..openers {
        let quota = per.min(count.saturating_sub(o * per));
        if quota == 0 {
            break;
        }
        let stop = stop.clone();
        let ready_tx = ready_tx.clone();
        threads.push(std::thread::spawn(move || {
            let mut held = Vec::with_capacity(quota);
            for _ in 0..quota {
                match Client::connect(addr) {
                    Ok(mut c) => {
                        if c.ping().is_err() {
                            break;
                        }
                        held.push(c);
                    }
                    Err(_) => break,
                }
            }
            let opened = held.len();
            let _ = ready_tx.send(());
            while !stop.load(Ordering::Relaxed) {
                std::thread::sleep(Duration::from_millis(25));
            }
            drop(held);
            opened
        }));
    }
    drop(ready_tx);
    for _ in &threads {
        let _ = ready_rx.recv();
    }
    (stop, threads)
}

/// Outcome of a reconnect storm ([`run_reconnect_storm`]).
#[derive(Debug, Default, Clone)]
pub struct StormReport {
    /// Full connect → ping → disconnect cycles that succeeded.
    pub reconnects: usize,
    /// Cycles that failed at any step (connect refused, ping error).
    pub errors: usize,
    pub elapsed_s: f64,
}

impl StormReport {
    pub fn reconnects_per_s(&self) -> f64 {
        if self.elapsed_s > 0.0 {
            self.reconnects as f64 / self.elapsed_s
        } else {
            0.0
        }
    }

    pub fn render(&self) -> String {
        format!(
            "storm: {} reconnects | {} errors | {:.0} reconnects/s",
            self.reconnects,
            self.errors,
            self.reconnects_per_s()
        )
    }
}

/// Burst-reconnect scenario: `connections` threads each run
/// connect → ping → disconnect cycles as fast as the server accepts
/// them, `cycles` cycles in total. Exercises the accept path, slab
/// slot recycling, and careful-close draining under churn — the
/// complement of the idle-population scenario.
pub fn run_reconnect_storm(
    addr: std::net::SocketAddr,
    connections: usize,
    cycles: usize,
) -> Result<StormReport> {
    anyhow::ensure!(connections > 0, "need at least one connection");
    let per = cycles.div_ceil(connections);
    let t0 = Instant::now();
    let mut threads = Vec::new();
    for c in 0..connections {
        let quota = per.min(cycles.saturating_sub(c * per));
        if quota == 0 {
            break;
        }
        threads.push(std::thread::spawn(move || {
            let (mut ok, mut errors) = (0usize, 0usize);
            for _ in 0..quota {
                match Client::connect(addr) {
                    Ok(mut client) => match client.ping() {
                        Ok(_) => ok += 1,
                        Err(_) => errors += 1,
                    },
                    Err(_) => errors += 1,
                }
            }
            (ok, errors)
        }));
    }
    let mut report = StormReport::default();
    for t in threads {
        let (ok, errors) = t.join().expect("storm thread panicked");
        report.reconnects += ok;
        report.errors += errors;
    }
    report.elapsed_s = t0.elapsed().as_secs_f64();
    Ok(report)
}

/// One point of an SLO sweep: the offered load and what came of it.
#[derive(Debug, Clone)]
pub struct SloPoint {
    pub rate_rps: f64,
    pub sent: usize,
    pub ok: usize,
    pub shed: usize,
    pub expired: usize,
    pub errors: usize,
    /// Deadline attainment among accepted requests (1.0 when nothing
    /// completed).
    pub attainment: f64,
    pub shed_rate: f64,
    pub p99_s: f64,
}

/// Drive the same deadline-carrying workload at a ladder of offered
/// rates (`rate_factors` × `config.rate_rps`) and report the attainment
/// and shed-rate curves — the "does overload degrade gracefully"
/// scenario: attainment among accepted requests should hold near 100%
/// while the shed rate absorbs the overload.
pub fn run_slo_sweep(
    addr: std::net::SocketAddr,
    config: &LoadGenConfig,
    rate_factors: &[f64],
) -> Result<Vec<SloPoint>> {
    anyhow::ensure!(config.rate_rps > 0.0, "SLO sweep needs a base rate (rate_rps > 0)");
    anyhow::ensure!(config.deadline_us > 0, "SLO sweep needs a deadline (deadline_us > 0)");
    let mut points = Vec::with_capacity(rate_factors.len());
    for (i, factor) in rate_factors.iter().enumerate() {
        let mut step = config.clone();
        step.rate_rps = config.rate_rps * factor;
        step.seed = config.seed.wrapping_add(i as u64);
        let report = run_loadgen(addr, step)?;
        points.push(SloPoint {
            rate_rps: config.rate_rps * factor,
            sent: report.sent,
            ok: report.ok,
            shed: report.shed,
            expired: report.expired,
            errors: report.errors,
            attainment: report.attainment().unwrap_or(1.0),
            shed_rate: report.shed_rate(),
            p99_s: report.p99_s(),
        });
    }
    Ok(points)
}

fn connection_worker(
    addr: std::net::SocketAddr,
    config: &LoadGenConfig,
    model: &str,
    quota: usize,
    warmup: usize,
    seed: u64,
) -> Result<ModelReport> {
    let mut client = Client::connect(addr)?;
    let mut rng = Pcg32::new(seed);
    let mut report = ModelReport::default();
    // Completed samples so far — the first `warmup` are excluded from
    // the latency vectors.
    let mut completed = 0usize;
    let sample = |rng: &mut Pcg32| -> Vec<f32> {
        (0..config.dim).map(|_| rng.uniform() as f32).collect()
    };
    // Per-connection share of the offered rate, Poisson arrivals.
    let conn_rate = config.rate_rps / config.connections as f64;
    let t0 = Instant::now();
    let mut next_arrival = 0.0f64;
    let mut pace = |rng: &mut Pcg32| {
        if conn_rate > 0.0 {
            let u: f64 = rng.uniform().max(1e-12);
            next_arrival += -u.ln() / conn_rate;
            let wait = next_arrival - t0.elapsed().as_secs_f64();
            if wait > 0.0 {
                std::thread::sleep(Duration::from_secs_f64(wait));
            }
        }
    };

    let qos = config.qos();
    let deadline_s = config.deadline_us as f64 / 1e6;
    if config.batch > 1 {
        let mut sent = 0usize;
        while sent < quota {
            let b = config.batch.min(quota - sent);
            let samples: Vec<Vec<f32>> = (0..b).map(|_| sample(&mut rng)).collect();
            pace(&mut rng);
            let t = Instant::now();
            match client.infer_batch_qos(config.backend, model, qos, &samples)? {
                BatchReply::Outputs(rows) => {
                    anyhow::ensure!(rows.len() == b, "batch reply size {} != {b}", rows.len());
                    report.ok += b;
                    let latency = t.elapsed().as_secs_f64();
                    if qos.has_deadline() && latency <= deadline_s {
                        report.deadline_met += b;
                    }
                    if completed >= warmup {
                        report.latencies.push(latency);
                    } else {
                        // A batch straddling the warm-up boundary is
                        // excluded whole — its latency is one sample.
                        report.warmup_excluded += b;
                    }
                    completed += b;
                }
                BatchReply::Shed(_) => report.shed += b,
                BatchReply::Failed { status: Status::Expired, .. } => report.expired += b,
                BatchReply::Failed { .. } => report.errors += b,
            }
            sent += b;
            report.sent += b;
        }
        return Ok(report);
    }

    // Single-sample path with a pipelining window.
    let window = config.pipeline.max(1);
    let mut in_flight: VecDeque<(u64, Instant)> = VecDeque::with_capacity(window);
    let drain_one = |client: &mut Client,
                     in_flight: &mut VecDeque<(u64, Instant)>,
                     report: &mut ModelReport,
                     completed: &mut usize|
     -> Result<()> {
        let (id, sent_at) = in_flight.pop_front().expect("drain on empty window");
        let (got, reply) = client.recv_infer()?;
        anyhow::ensure!(got == id, "reply {got} out of order (expected {id})");
        match reply {
            InferReply::Output(_) => {
                report.ok += 1;
                let latency = sent_at.elapsed().as_secs_f64();
                if qos.has_deadline() && latency <= deadline_s {
                    report.deadline_met += 1;
                }
                if *completed >= warmup {
                    report.latencies.push(latency);
                } else {
                    report.warmup_excluded += 1;
                }
                *completed += 1;
            }
            InferReply::Shed(_) => report.shed += 1,
            InferReply::Failed { status: Status::Expired, .. } => report.expired += 1,
            InferReply::Failed { .. } => report.errors += 1,
        }
        Ok(())
    };
    for _ in 0..quota {
        if in_flight.len() >= window {
            drain_one(&mut client, &mut in_flight, &mut report, &mut completed)?;
        }
        let x = sample(&mut rng);
        pace(&mut rng);
        let id = client.send_infer_qos(config.backend, model, qos, &x)?;
        in_flight.push_back((id, Instant::now()));
        report.sent += 1;
    }
    while !in_flight.is_empty() {
        drain_one(&mut client, &mut in_flight, &mut report, &mut completed)?;
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 5,
            base_backoff: Duration::from_millis(10),
            max_backoff: Duration::from_millis(200),
            jitter: 0.5,
            attempt_timeout: Duration::from_secs(1),
        }
    }

    #[test]
    fn backoff_schedule_doubles_then_caps() {
        // jitter = 0 makes the schedule exact: 10, 20, 40, 80, 160,
        // then pinned at the 200 ms cap.
        let p = RetryPolicy { jitter: 0.0, ..policy() };
        let mut rng = Pcg32::new(1);
        let ms: Vec<u128> =
            (0..7).map(|i| p.backoff_for(i, &mut rng).as_millis()).collect();
        assert_eq!(ms, vec![10, 20, 40, 80, 160, 200, 200]);
    }

    #[test]
    fn jitter_stays_inside_declared_bounds() {
        let p = policy();
        let mut rng = Pcg32::new(42);
        for retry in 0..6u32 {
            let nominal =
                (p.base_backoff.as_secs_f64() * 2f64.powi(retry as i32))
                    .min(p.max_backoff.as_secs_f64());
            for _ in 0..200 {
                let d = p.backoff_for(retry, &mut rng).as_secs_f64();
                assert!(
                    d <= nominal + 1e-9 && d >= nominal * (1.0 - p.jitter) - 1e-9,
                    "retry {retry}: {d}s outside [{}, {nominal}]",
                    nominal * (1.0 - p.jitter)
                );
            }
        }
    }

    #[test]
    fn jittered_backoffs_are_deterministic_per_seed_and_spread() {
        let p = policy();
        let seq = |seed: u64| -> Vec<Duration> {
            let mut rng = Pcg32::new(seed);
            (0..8).map(|i| p.backoff_for(i, &mut rng)).collect()
        };
        assert_eq!(seq(7), seq(7), "same seed must reproduce the schedule");
        assert_ne!(seq(7), seq(8), "different seeds should de-synchronize clients");
        // Two same-retry draws from one stream differ (herd spreading).
        let mut rng = Pcg32::new(3);
        let a = p.backoff_for(3, &mut rng);
        let b = p.backoff_for(3, &mut rng);
        assert_ne!(a, b);
    }

    #[test]
    fn huge_retry_index_does_not_overflow() {
        let p = policy();
        let mut rng = Pcg32::new(1);
        // 2^retry would overflow f64 exponent ranges for huge retries;
        // the cap keeps it finite and at max_backoff.
        let d = p.backoff_for(u32::MAX, &mut rng);
        assert!(d <= p.max_backoff);
    }

    #[test]
    fn connect_failures_exhaust_the_attempt_budget() {
        // An address nothing listens on: every attempt is a connect
        // failure, and after max_attempts the last error surfaces.
        let addr: SocketAddr = {
            // Bind-then-drop yields a port that is closed right after.
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap()
        };
        let p = RetryPolicy {
            max_attempts: 3,
            base_backoff: Duration::from_millis(1),
            max_backoff: Duration::from_millis(2),
            jitter: 0.0,
            attempt_timeout: Duration::from_millis(200),
        };
        let mut c = RetryingClient::new(addr, p, 11);
        let err = c
            .infer_qos(BACKEND_ANY, "", Qos::NONE, &[0.0])
            .expect_err("no server — must exhaust retries");
        assert!(format!("{err:#}").contains("after 3 attempts"), "{err:#}");
        // Counter semantics: 3 attempts, of which 2 were retries.
        assert_eq!(c.attempts_total(), 3);
        assert_eq!(c.retries_total(), 2);
        // A second logical request keeps accumulating.
        let _ = c.infer_qos(BACKEND_ANY, "", Qos::NONE, &[0.0]);
        assert_eq!(c.attempts_total(), 6);
        assert_eq!(c.retries_total(), 4);
    }
}
