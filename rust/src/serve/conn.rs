//! Per-connection state machine for the event-driven serving layer.
//!
//! One [`Conn`] owns a nonblocking socket, an incremental
//! [`FrameAssembler`] for the read side, and an ordered writeback queue
//! of [`Outgoing`] items for the write side. The event loop in
//! `serve/server.rs` drives it: readable events feed [`Conn::read_ready`]
//! (which returns the complete frames decoded this pass), dispatch
//! enqueues one [`Outgoing`] per request, and [`Conn::pump`] resolves
//! the queue head and flushes bytes whenever the socket, a coordinator
//! completion, or a timer says progress is possible.
//!
//! Ordering guarantee: responses leave in request order. Only the queue
//! *head* is ever resolved; a pending head blocks everything behind it
//! exactly like the old per-connection writer thread did, and its
//! response deadline starts when it becomes head — matching the old
//! `recv_timeout(response_timeout)` semantics item for item.
//!
//! Close discipline (mirrors the thread-based server byte for byte):
//!
//! - *clean* close (peer EOF, shutdown): flush the queue, then close.
//! - *careful* close (framing error, read timeout, Busy): flush the
//!   goodbye frame, send our FIN, then discard inbound bytes for up to
//!   [`DRAIN_BUDGET`] (or until the peer's FIN) so the error frame is
//!   not destroyed by a RST on common TCP stacks.

use super::wire::{self, Frame, FrameAssembler, Opcode, Status};
use crate::coordinator::request::{CompletionNotify, FailureKind, InferResult};
use crate::serve::poll::WakePipe;
use std::collections::VecDeque;
use std::io::{self, Read, Write};
use std::net::TcpStream;
use std::sync::mpsc::{Receiver, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// How long a careful close keeps discarding inbound bytes while
/// waiting for the peer's FIN (the old `drain_then_close` budget).
pub const DRAIN_BUDGET: Duration = Duration::from_millis(250);

/// A connection whose write buffer makes no progress for this long is
/// force-closed — the old writer thread's `set_write_timeout` bound.
pub const WRITE_STALL: Duration = Duration::from_secs(10);

/// Cap on bytes consumed from one socket per readable event, so a
/// firehose peer cannot starve the rest of the loop. Level-triggered
/// polling re-reports the socket until it is drained.
const READ_PASS_BYTES: usize = 256 * 1024;

/// Work items queued for writeback, in request order. `version` is the
/// protocol version of the request being answered — the response frame
/// echoes it.
pub enum Outgoing {
    /// Response already known (ping, stats, errors, swap results).
    Ready(Frame),
    /// Waiting on one coordinator response. `deadline` arms lazily when
    /// the item reaches the queue head.
    Pending {
        version: u16,
        request_id: u64,
        rx: Receiver<InferResult>,
        deadline: Option<Instant>,
    },
    /// Waiting on a whole submitted batch; `rows` collects resolved
    /// outputs and `next` indexes the first unresolved receiver. One
    /// deadline covers the whole batch (a per-receiver timeout would
    /// multiply worst-case head-of-line blocking by the batch size).
    PendingBatch {
        version: u16,
        request_id: u64,
        receivers: Vec<Receiver<InferResult>>,
        rows: Vec<Vec<f32>>,
        next: usize,
        deadline: Option<Instant>,
    },
}

/// The wire status one coordinator failure maps to.
pub fn failure_status(kind: FailureKind) -> Status {
    match kind {
        FailureKind::Backend => Status::BackendError,
        FailureKind::Expired => Status::Expired,
    }
}

/// What one readable event produced.
#[derive(Default)]
pub struct ReadPass {
    /// Complete frames decoded this pass, in arrival order.
    pub frames: Vec<Frame>,
    /// Framing-level protocol error: answer once, then careful-close.
    /// Frames in `frames` arrived *before* the poison byte and must
    /// still be dispatched first.
    pub framing_error: Option<String>,
}

/// One registered connection.
pub struct Conn {
    stream: TcpStream,
    /// Slab-slot reuse guard: timer entries and completion notifies
    /// carry the generation they were created for and are ignored once
    /// the slot is recycled.
    pub generation: u64,
    assembler: FrameAssembler,
    outq: VecDeque<Outgoing>,
    /// Serialized-but-unsent response bytes (`wpos` = flushed prefix).
    wbuf: Vec<u8>,
    wpos: usize,
    /// No more requests will be dispatched (close in progress).
    pub closing: bool,
    /// Careful close: FIN + drain so a goodbye frame survives.
    careful: bool,
    /// Peer sent its FIN.
    pub peer_eof: bool,
    fin_sent: bool,
    /// Socket is dead (reset, I/O error) — tear down immediately.
    broken: bool,
    drain_deadline: Option<Instant>,
    /// Per-frame read deadline (slowloris defense). Restarts when a
    /// complete frame arrives, never on partial bytes — identical to
    /// the blocking reader, whose deadline covered the whole frame.
    pub read_deadline: Option<Instant>,
    read_timeout: Duration,
    response_timeout: Duration,
    last_write_progress: Instant,
    /// Whether this connection occupies a slot in `active_conns`
    /// (Busy-rejected connections do not).
    pub counted: bool,
    /// Earliest timer-wheel entry armed for this connection, so the
    /// loop re-arms only when a deadline moves earlier.
    pub timer_armed_for: Option<Instant>,
}

impl Conn {
    pub fn new(
        stream: TcpStream,
        generation: u64,
        now: Instant,
        read_timeout: Duration,
        response_timeout: Duration,
    ) -> Conn {
        Conn {
            stream,
            generation,
            assembler: FrameAssembler::new(),
            outq: VecDeque::new(),
            wbuf: Vec::new(),
            wpos: 0,
            closing: false,
            careful: false,
            peer_eof: false,
            fin_sent: false,
            broken: false,
            drain_deadline: None,
            read_deadline: Some(now + read_timeout),
            read_timeout,
            response_timeout,
            last_write_progress: now,
            counted: true,
            timer_armed_for: None,
        }
    }

    pub fn stream(&self) -> &TcpStream {
        &self.stream
    }

    /// Begin closing: queued responses still flush; `careful` adds the
    /// FIN-then-drain tail that protects a just-queued goodbye frame.
    pub fn begin_close(&mut self, careful: bool) {
        self.closing = true;
        self.careful = self.careful || careful;
        self.read_deadline = None;
    }

    pub fn enqueue(&mut self, out: Outgoing) {
        self.outq.push_back(out);
    }

    /// Unflushed response bytes (the `pending_writeback_bytes` gauge).
    pub fn writeback_bytes(&self) -> u64 {
        (self.wbuf.len() - self.wpos) as u64
    }

    /// Consume whatever the socket has (bounded per pass) and decode
    /// complete frames. While closing we only discard inbound bytes,
    /// watching for the peer's FIN.
    pub fn read_ready(&mut self, now: Instant, max_payload: u32) -> ReadPass {
        let mut pass = ReadPass::default();
        let mut buf = [0u8; 16 * 1024];
        let mut taken = 0usize;
        let mut saw_eof = false;
        while taken < READ_PASS_BYTES {
            match self.stream.read(&mut buf) {
                Ok(0) => {
                    saw_eof = true;
                    break;
                }
                Ok(n) => {
                    taken += n;
                    if !self.closing {
                        self.assembler.push(&buf[..n]);
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(_) => {
                    self.broken = true;
                    return pass;
                }
            }
        }
        if self.closing {
            if saw_eof {
                self.peer_eof = true;
            }
            return pass;
        }
        loop {
            match self.assembler.next_frame(max_payload) {
                Ok(Some(frame)) => {
                    // A complete frame restarts the per-frame deadline.
                    self.read_deadline = Some(now + self.read_timeout);
                    pass.frames.push(frame);
                }
                Ok(None) => break,
                Err(msg) => {
                    pass.framing_error = Some(msg);
                    break;
                }
            }
        }
        if saw_eof {
            // The peer's FIN arrived in this pass; a careful close need
            // not wait for another readiness event to observe it.
            self.peer_eof = true;
            if pass.framing_error.is_none() {
                if self.assembler.is_mid_frame() {
                    // EOF inside a frame is a truncation, not a clean
                    // close — same diagnostic as the blocking reader.
                    pass.framing_error = Some(FrameAssembler::eof_mid_frame());
                } else {
                    self.read_deadline = None;
                }
            }
        }
        pass
    }

    /// Resolve as much of the writeback queue head as possible and
    /// flush serialized bytes to the socket. Call whenever the socket
    /// became writable, a completion notify fired, or a timer expired.
    pub fn pump(&mut self, now: Instant) {
        if self.broken {
            return;
        }
        self.resolve_heads(now);
        self.flush(now);
        if self.wpos == self.wbuf.len() {
            self.wbuf.clear();
            self.wpos = 0;
        }
        // Fully flushed and nothing queued: take the careful-close FIN
        // step (clean closes just report done()).
        if self.closing
            && self.outq.is_empty()
            && self.wbuf.is_empty()
            && self.careful
            && !self.fin_sent
        {
            let _ = self.stream.shutdown(std::net::Shutdown::Write);
            self.fin_sent = true;
            self.drain_deadline = Some(now + DRAIN_BUDGET);
        }
    }

    /// Serialize every head item that is already resolvable into
    /// `wbuf`, stopping at the first one still waiting — ordered
    /// writeback, exactly like the old writer thread.
    fn resolve_heads(&mut self, now: Instant) {
        while let Some(head) = self.outq.pop_front() {
            match self.resolve_one(head, now) {
                Ok(frame) => {
                    // Vec<u8> is an infallible writer.
                    let _ = wire::write_frame(&mut self.wbuf, &frame);
                }
                Err(unresolved) => {
                    self.outq.push_front(unresolved);
                    return;
                }
            }
        }
    }

    /// Resolve one item to its response frame, or hand it back if its
    /// result has not arrived (and its deadline has not passed).
    fn resolve_one(&self, head: Outgoing, now: Instant) -> Result<Frame, Outgoing> {
        match head {
            Outgoing::Ready(f) => Ok(f),
            Outgoing::Pending { version, request_id, rx, mut deadline } => {
                // The response clock starts when the item becomes head
                // — the old writer's recv_timeout(response_timeout).
                let d = *deadline.get_or_insert(now + self.response_timeout);
                match rx.try_recv() {
                    Ok(Ok(resp)) => Ok(Frame::ok(
                        Opcode::Infer,
                        request_id,
                        wire::encode_outputs(&resp.output),
                    )
                    .at_version(version)),
                    Ok(Err(e)) => Ok(Frame::error(
                        Opcode::Infer,
                        request_id,
                        failure_status(e.kind),
                        &e.message,
                    )
                    .at_version(version)),
                    Err(TryRecvError::Disconnected) => {
                        Ok(lost_frame(Opcode::Infer, request_id, version))
                    }
                    Err(TryRecvError::Empty) if now >= d => {
                        Ok(lost_frame(Opcode::Infer, request_id, version))
                    }
                    Err(TryRecvError::Empty) => {
                        Err(Outgoing::Pending { version, request_id, rx, deadline })
                    }
                }
            }
            Outgoing::PendingBatch {
                version,
                request_id,
                receivers,
                mut rows,
                mut next,
                mut deadline,
            } => {
                let d = *deadline.get_or_insert(now + self.response_timeout);
                loop {
                    if next >= receivers.len() {
                        return Ok(Frame::ok(
                            Opcode::InferBatch,
                            request_id,
                            wire::encode_batch_outputs(&rows),
                        )
                        .at_version(version));
                    }
                    match receivers[next].try_recv() {
                        Ok(Ok(resp)) => {
                            rows.push(resp.output);
                            next += 1;
                        }
                        // One failure fails the whole batch.
                        Ok(Err(e)) => {
                            return Ok(Frame::error(
                                Opcode::InferBatch,
                                request_id,
                                failure_status(e.kind),
                                &e.message,
                            )
                            .at_version(version))
                        }
                        Err(TryRecvError::Disconnected) => {
                            return Ok(lost_frame(Opcode::InferBatch, request_id, version))
                        }
                        Err(TryRecvError::Empty) if now >= d => {
                            return Ok(lost_frame(Opcode::InferBatch, request_id, version))
                        }
                        Err(TryRecvError::Empty) => {
                            return Err(Outgoing::PendingBatch {
                                version,
                                request_id,
                                receivers,
                                rows,
                                next,
                                deadline,
                            })
                        }
                    }
                }
            }
        }
    }

    /// Push `wbuf` bytes at the socket until done or `WouldBlock`.
    fn flush(&mut self, now: Instant) {
        while self.wpos < self.wbuf.len() {
            match self.stream.write(&self.wbuf[self.wpos..]) {
                Ok(0) => {
                    self.broken = true;
                    return;
                }
                Ok(n) => {
                    self.wpos += n;
                    self.last_write_progress = now;
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
                Err(_) => {
                    self.broken = true;
                    return;
                }
            }
        }
    }

    /// Read interest: serving connections always listen; a careful
    /// close keeps listening (to discard) until the peer's FIN.
    pub fn want_read(&self) -> bool {
        if self.broken || self.peer_eof {
            return false;
        }
        if self.closing {
            self.careful && self.fin_sent
        } else {
            true
        }
    }

    /// Write interest: only while flushed-but-unsent bytes exist (a
    /// pending head needs a completion notify, not socket readiness).
    pub fn want_write(&self) -> bool {
        !self.broken && self.wpos < self.wbuf.len()
    }

    /// The earliest instant at which this connection needs a timer
    /// kick: per-frame read deadline, head response deadline, careful
    /// drain budget, or the write-stall bound.
    pub fn next_deadline(&self) -> Option<Instant> {
        let mut next: Option<Instant> = None;
        let mut fold = |d: Option<Instant>| {
            next = match (next, d) {
                (Some(a), Some(b)) => Some(a.min(b)),
                (a, b) => a.or(b),
            };
        };
        if !self.closing && !self.peer_eof {
            fold(self.read_deadline);
        }
        fold(match self.outq.front() {
            Some(Outgoing::Pending { deadline, .. })
            | Some(Outgoing::PendingBatch { deadline, .. }) => *deadline,
            _ => None,
        });
        fold(self.drain_deadline);
        if self.wpos < self.wbuf.len() {
            fold(Some(self.last_write_progress + WRITE_STALL));
        }
        next
    }

    /// The read deadline fired: the peer stalled mid-frame (or went
    /// silent) past the timeout.
    pub fn read_deadline_expired(&self, now: Instant) -> bool {
        !self.closing && self.read_deadline.is_some_and(|d| now >= d)
    }

    /// True once the connection should be torn down and its slot freed.
    pub fn done(&self, now: Instant) -> bool {
        if self.broken {
            return true;
        }
        // A peer that stops reading while we still owe it bytes would
        // pin the slot forever; the old writer thread bounded this with
        // a 10s write timeout.
        if self.wpos < self.wbuf.len() && now >= self.last_write_progress + WRITE_STALL {
            return true;
        }
        if !(self.closing && self.outq.is_empty() && self.wbuf.len() == self.wpos) {
            return false;
        }
        if !self.careful {
            return true;
        }
        // Careful close: wait for the peer's FIN or the drain budget.
        self.fin_sent && (self.peer_eof || self.drain_deadline.is_some_and(|d| now >= d))
    }
}

/// The frame answering a response channel that died or timed out —
/// identical text to the old writer thread's.
fn lost_frame(opcode: Opcode, request_id: u64, version: u16) -> Frame {
    Frame::error(opcode, request_id, Status::Internal, "response channel lost or timed out")
        .at_version(version)
}

/// Completion mailbox between coordinator worker threads and the event
/// loop: workers push the finished connection's token and tap the wake
/// pipe; the loop drains the tokens on its next pass and pumps those
/// connections.
pub struct NotifyHub {
    wake: WakePipe,
    ready: Mutex<Vec<u64>>,
}

impl NotifyHub {
    pub fn new(wake: WakePipe) -> NotifyHub {
        NotifyHub { wake, ready: Mutex::new(Vec::new()) }
    }

    pub fn wake_fd(&self) -> std::os::unix::io::RawFd {
        self.wake.read_fd()
    }

    /// Nudge the loop without marking any connection ready (shutdown).
    pub fn wake(&self) {
        self.wake.wake();
    }

    /// A completion hook bound to one connection token. Cheap to clone
    /// per request (it is an `Arc`).
    pub fn notifier(self: &Arc<Self>, token: u64) -> CompletionNotify {
        let hub = self.clone();
        Arc::new(move || hub.push(token))
    }

    fn push(&self, token: u64) {
        let was_empty = {
            let mut ready = self.ready.lock().unwrap();
            let was_empty = ready.is_empty();
            ready.push(token);
            was_empty
        };
        // One wake byte per batch of completions: with tokens already
        // queued a wakeup is guaranteed to be pending (or the loop is
        // mid-pass and will swap the vec before sleeping).
        if was_empty {
            self.wake.wake();
        }
    }

    /// Swallow pending wake bytes and take the ready-token batch.
    pub fn drain_ready(&self, out: &mut Vec<u64>) {
        self.wake.drain();
        out.clear();
        let mut ready = self.ready.lock().unwrap();
        std::mem::swap(out, &mut ready);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn pair() -> (TcpStream, TcpStream) {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let client = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();
        (client, server)
    }

    #[test]
    fn ordered_writeback_blocks_behind_a_pending_head() {
        let (mut client, server) = pair();
        let now = Instant::now();
        let mut conn =
            Conn::new(server, 0, now, Duration::from_secs(30), Duration::from_secs(30));
        let (tx, rx) = channel::<InferResult>();
        conn.enqueue(Outgoing::Pending { version: 1, request_id: 1, rx, deadline: None });
        conn.enqueue(Outgoing::Ready(Frame::ok(Opcode::Ping, 2, vec![]).at_version(1)));
        conn.pump(now);
        assert_eq!(conn.writeback_bytes(), 0, "nothing resolvable yet");

        tx.send(Ok(crate::coordinator::request::InferResponse {
            id: 1,
            output: vec![1.0],
            latency_s: 0.0,
            backend: "t".into(),
            batch_size: 1,
        }))
        .unwrap();
        conn.pump(now);
        client.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
        let mut r = std::io::BufReader::new(&mut client);
        let f1 = wire::read_frame(&mut r, wire::DEFAULT_MAX_PAYLOAD).unwrap();
        let f2 = wire::read_frame(&mut r, wire::DEFAULT_MAX_PAYLOAD).unwrap();
        assert_eq!((f1.request_id, f1.status), (1, Status::Ok));
        assert_eq!((f2.request_id, f2.status), (2, Status::Ok));
    }

    #[test]
    fn head_deadline_is_armed_lazily_and_times_out_to_internal() {
        let (mut client, server) = pair();
        let t0 = Instant::now();
        let mut conn =
            Conn::new(server, 0, t0, Duration::from_secs(30), Duration::from_millis(100));
        let (_tx, rx) = channel::<InferResult>();
        conn.enqueue(Outgoing::Pending { version: 1, request_id: 9, rx, deadline: None });
        conn.pump(t0);
        assert_eq!(
            conn.next_deadline(),
            Some(t0 + Duration::from_millis(100)),
            "head deadline armed when the item became head, earlier than the read deadline"
        );
        conn.pump(t0 + Duration::from_millis(100));
        client.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
        let mut r = std::io::BufReader::new(&mut client);
        let f = wire::read_frame(&mut r, wire::DEFAULT_MAX_PAYLOAD).unwrap();
        assert_eq!(f.status, Status::Internal);
        assert!(f.message().contains("lost or timed out"));
    }

    #[test]
    fn careful_close_sends_fin_after_the_goodbye_and_waits_for_peer() {
        let (mut client, server) = pair();
        let t0 = Instant::now();
        let mut conn =
            Conn::new(server, 0, t0, Duration::from_secs(30), Duration::from_secs(30));
        conn.enqueue(Outgoing::Ready(
            Frame::error(Opcode::Ping, 0, Status::Busy, "server connection limit reached")
                .at_version(wire::MIN_VERSION),
        ));
        conn.begin_close(true);
        conn.pump(t0);
        assert!(!conn.done(t0), "drain window still open");
        assert!(conn.want_read(), "discarding until the peer's FIN");

        client.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
        let mut r = std::io::BufReader::new(&mut client);
        let f = wire::read_frame(&mut r, wire::DEFAULT_MAX_PAYLOAD).unwrap();
        assert_eq!(f.status, Status::Busy);
        drop(r);
        drop(client); // peer FIN
        std::thread::sleep(Duration::from_millis(20));
        let now = Instant::now();
        let pass = conn.read_ready(now, wire::DEFAULT_MAX_PAYLOAD);
        assert!(pass.frames.is_empty() && pass.framing_error.is_none());
        assert!(conn.done(now), "peer FIN completes the careful close");
    }

    #[test]
    fn read_pass_reports_frames_then_poison_in_order() {
        let (mut client, server) = pair();
        let now = Instant::now();
        let mut conn =
            Conn::new(server, 0, now, Duration::from_secs(30), Duration::from_secs(30));
        let mut bytes = Vec::new();
        wire::write_frame(&mut bytes, &Frame::ok(Opcode::Ping, 1, vec![]).at_version(1)).unwrap();
        wire::write_frame(&mut bytes, &Frame::ok(Opcode::Ping, 2, vec![]).at_version(1)).unwrap();
        bytes.extend_from_slice(&[0xde; 32]);
        client.write_all(&bytes).unwrap();
        std::thread::sleep(Duration::from_millis(20));
        let pass = conn.read_ready(now, wire::DEFAULT_MAX_PAYLOAD);
        assert_eq!(pass.frames.len(), 2, "valid frames before the poison still dispatch");
        assert!(pass.framing_error.unwrap().contains("magic"));
    }

    #[test]
    fn notify_hub_batches_tokens_across_threads() {
        let hub = Arc::new(NotifyHub::new(WakePipe::new().unwrap()));
        let n1 = hub.notifier(3);
        let n2 = hub.notifier(8);
        let t = std::thread::spawn(move || n2());
        n1();
        t.join().unwrap();
        n1();
        let mut out = Vec::new();
        hub.drain_ready(&mut out);
        out.sort_unstable();
        assert_eq!(out, vec![3, 3, 8]);
        hub.drain_ready(&mut out);
        assert!(out.is_empty());
    }
}
