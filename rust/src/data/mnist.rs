//! Loader for the real MNIST idx files (optionally gzip-compressed),
//! used when `MNIST_DIR` is set. File names follow the canonical
//! distribution: `train-images-idx3-ubyte[.gz]`, `train-labels-idx1-ubyte[.gz]`,
//! `t10k-images-idx3-ubyte[.gz]`, `t10k-labels-idx1-ubyte[.gz]`.

use super::Dataset;
use crate::nn::tensor::Matrix;
use anyhow::{bail, Context, Result};
use std::io::Read;
use std::path::Path;

const IMG_MAGIC: u32 = 0x0000_0803;
const LBL_MAGIC: u32 = 0x0000_0801;

/// Read a possibly-gzipped file fully into memory.
fn read_maybe_gz(dir: &Path, stem: &str) -> Result<Vec<u8>> {
    let plain = dir.join(stem);
    let gz = dir.join(format!("{stem}.gz"));
    if plain.exists() {
        Ok(std::fs::read(&plain)?)
    } else if gz.exists() {
        let raw = std::fs::read(&gz)?;
        let mut out = Vec::new();
        flate2_decode(&raw, &mut out)?;
        Ok(out)
    } else {
        bail!("neither {} nor {} exists", plain.display(), gz.display());
    }
}

/// Minimal gzip inflate via the vendored `flate2`-free fallback: the
/// offline vendor set does include `flate2`'s sibling `miniz_oxide` only
/// as a transitive dep of `zip`, so we use `zip`'s re-export path is not
/// public — instead parse the gzip container and inflate with
/// `miniz_oxide` is unavailable as a direct dep. We therefore shell out
/// to nothing: idx files are expected *uncompressed* unless gzip support
/// is compiled in. To keep the loader honest we detect gzip magic and
/// error with a clear message.
fn flate2_decode(raw: &[u8], _out: &mut Vec<u8>) -> Result<()> {
    if raw.len() >= 2 && raw[0] == 0x1f && raw[1] == 0x8b {
        bail!(
            "gzipped idx files are not supported in the offline build; \
             gunzip them in MNIST_DIR first"
        );
    }
    bail!("unrecognized compressed idx file");
}

fn be_u32(bytes: &[u8], pos: usize) -> Result<u32> {
    if pos + 4 > bytes.len() {
        bail!("idx file truncated at {pos}");
    }
    Ok(u32::from_be_bytes(bytes[pos..pos + 4].try_into().unwrap()))
}

/// Parse an idx3 image file into `(n, rows, cols, pixels)`.
fn parse_images(bytes: &[u8]) -> Result<(usize, usize, usize, &[u8])> {
    if be_u32(bytes, 0)? != IMG_MAGIC {
        bail!("bad image magic");
    }
    let n = be_u32(bytes, 4)? as usize;
    let rows = be_u32(bytes, 8)? as usize;
    let cols = be_u32(bytes, 12)? as usize;
    let need = 16 + n * rows * cols;
    if bytes.len() < need {
        bail!("image file too short: {} < {need}", bytes.len());
    }
    Ok((n, rows, cols, &bytes[16..need]))
}

/// Parse an idx1 label file.
fn parse_labels(bytes: &[u8]) -> Result<&[u8]> {
    if be_u32(bytes, 0)? != LBL_MAGIC {
        bail!("bad label magic");
    }
    let n = be_u32(bytes, 4)? as usize;
    if bytes.len() < 8 + n {
        bail!("label file too short");
    }
    Ok(&bytes[8..8 + n])
}

fn to_dataset(images: &[u8], labels: &[u8], d: usize, cap: usize) -> Dataset {
    let n = (labels.len()).min(cap);
    let mut inputs = Matrix::zeros(n, d);
    for (i, px) in images.chunks(d).take(n).enumerate() {
        for (o, &b) in inputs.data[i * d..(i + 1) * d].iter_mut().zip(px) {
            *o = b as f32 / 255.0;
        }
    }
    Dataset {
        inputs,
        labels: labels.iter().take(n).map(|&l| l as usize).collect(),
        classes: 10,
        source: "mnist".into(),
    }
}

/// Load `(train, test)` capped at the requested sizes.
pub fn load_mnist(dir: &Path, n_train: usize, n_test: usize) -> Result<(Dataset, Dataset)> {
    let train_imgs = read_maybe_gz(dir, "train-images-idx3-ubyte").context("train images")?;
    let train_lbls = read_maybe_gz(dir, "train-labels-idx1-ubyte").context("train labels")?;
    let test_imgs = read_maybe_gz(dir, "t10k-images-idx3-ubyte").context("test images")?;
    let test_lbls = read_maybe_gz(dir, "t10k-labels-idx1-ubyte").context("test labels")?;

    let (tn, tr, tc, tpx) = parse_images(&train_imgs)?;
    let tl = parse_labels(&train_lbls)?;
    if tn != tl.len() {
        bail!("train image/label count mismatch: {tn} vs {}", tl.len());
    }
    let (en, er, ec, epx) = parse_images(&test_imgs)?;
    let el = parse_labels(&test_lbls)?;
    if en != el.len() {
        bail!("test image/label count mismatch");
    }
    if (tr, tc) != (28, 28) || (er, ec) != (28, 28) {
        bail!("expected 28x28 images, got {tr}x{tc} / {er}x{ec}");
    }
    Ok((
        to_dataset(tpx, tl, tr * tc, n_train),
        to_dataset(epx, el, er * ec, n_test),
    ))
}

// Silence the unused import when gzip path is never hit.
#[allow(dead_code)]
fn _read_unused<R: Read>(_: R) {}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a tiny idx pair in a temp dir and load it back.
    fn write_fake_mnist(dir: &Path, n: usize) {
        std::fs::create_dir_all(dir).unwrap();
        let mut img = Vec::new();
        img.extend_from_slice(&IMG_MAGIC.to_be_bytes());
        img.extend_from_slice(&(n as u32).to_be_bytes());
        img.extend_from_slice(&28u32.to_be_bytes());
        img.extend_from_slice(&28u32.to_be_bytes());
        for i in 0..n * 784 {
            img.push((i % 251) as u8);
        }
        let mut lbl = Vec::new();
        lbl.extend_from_slice(&LBL_MAGIC.to_be_bytes());
        lbl.extend_from_slice(&(n as u32).to_be_bytes());
        for i in 0..n {
            lbl.push((i % 10) as u8);
        }
        for stem in ["train-images-idx3-ubyte", "t10k-images-idx3-ubyte"] {
            std::fs::write(dir.join(stem), &img).unwrap();
        }
        for stem in ["train-labels-idx1-ubyte", "t10k-labels-idx1-ubyte"] {
            std::fs::write(dir.join(stem), &lbl).unwrap();
        }
    }

    #[test]
    fn loads_idx_files() {
        let dir = std::env::temp_dir().join("edgemlp_mnist_test");
        write_fake_mnist(&dir, 12);
        let (train, test) = load_mnist(&dir, 10, 5).unwrap();
        assert_eq!(train.len(), 10);
        assert_eq!(test.len(), 5);
        assert_eq!(train.inputs.cols, 784);
        assert_eq!(train.labels[3], 3);
        assert!(train.inputs.data.iter().all(|&v| (0.0..=1.0).contains(&v)));
        assert_eq!(train.source, "mnist");
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("edgemlp_mnist_badmagic");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("train-images-idx3-ubyte"), [0u8; 32]).unwrap();
        std::fs::write(dir.join("train-labels-idx1-ubyte"), [0u8; 16]).unwrap();
        std::fs::write(dir.join("t10k-images-idx3-ubyte"), [0u8; 32]).unwrap();
        std::fs::write(dir.join("t10k-labels-idx1-ubyte"), [0u8; 16]).unwrap();
        assert!(load_mnist(&dir, 5, 5).is_err());
    }

    #[test]
    fn rejects_gzip_with_clear_error() {
        let dir = std::env::temp_dir().join("edgemlp_mnist_gz");
        std::fs::create_dir_all(&dir).unwrap();
        // Remove any plain file a previous test run left behind.
        let _ = std::fs::remove_file(dir.join("train-images-idx3-ubyte"));
        std::fs::write(dir.join("train-images-idx3-ubyte.gz"), [0x1f, 0x8b, 0, 0]).unwrap();
        let err = load_mnist(&dir, 5, 5).unwrap_err();
        assert!(format!("{err:#}").contains("gunzip"), "err: {err:#}");
    }

    #[test]
    fn missing_dir_errors() {
        assert!(load_mnist(Path::new("/nonexistent_mnist"), 5, 5).is_err());
    }
}
