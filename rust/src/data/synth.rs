//! Deterministic synthetic 28×28 digit generator — the offline stand-in
//! for MNIST (DESIGN.md §5).
//!
//! Each digit class is a polyline skeleton (a seven-segment-style glyph
//! with diagonals for 4/7); a sample applies a random affine jitter
//! (translation, rotation, scale), draws the strokes with a soft
//! distance-falloff pen, and adds pixel noise. The result has MNIST's
//! shape (784 inputs in `[0,1]`, 10 classes) and non-trivial intra-class
//! variation, which is what the quantization-accuracy experiments need.

use super::Dataset;
use crate::nn::tensor::Matrix;
use crate::util::rng::Pcg32;

const SIZE: usize = 28;

/// Segment endpoints in a normalized [0,1]² glyph box.
type Seg = ((f32, f32), (f32, f32));

/// Polyline skeletons per digit. Coordinates are (x, y) with y downward.
fn skeleton(digit: usize) -> Vec<Seg> {
    // Seven-segment corner points.
    let (l, r, t, m, b) = (0.2f32, 0.8f32, 0.1f32, 0.5f32, 0.9f32);
    let top = ((l, t), (r, t));
    let mid = ((l, m), (r, m));
    let bot = ((l, b), (r, b));
    let tl = ((l, t), (l, m));
    let tr = ((r, t), (r, m));
    let bl = ((l, m), (l, b));
    let br = ((r, m), (r, b));
    match digit {
        0 => vec![top, bot, tl, tr, bl, br],
        1 => vec![tr, br, ((0.55, t), (r, t))],
        2 => vec![top, tr, mid, bl, bot],
        3 => vec![top, tr, mid, br, bot],
        4 => vec![tl, mid, tr, br, ((r, t), (l, m))],
        5 => vec![top, tl, mid, br, bot],
        6 => vec![top, tl, mid, br, bot, bl],
        7 => vec![top, ((r, t), (0.4, b))],
        8 => vec![top, mid, bot, tl, tr, bl, br],
        9 => vec![top, mid, bot, tl, tr, br],
        other => panic!("digit {other} out of range"),
    }
}

/// Render one jittered digit into a 784-length buffer.
pub fn render_digit(digit: usize, rng: &mut Pcg32) -> Vec<f32> {
    let segs = skeleton(digit);
    // Affine jitter parameters.
    let angle = rng.range(-0.17, 0.17) as f32; // ±10°
    let scale = rng.range(0.85, 1.1) as f32;
    let dx = rng.range(-1.5, 1.5) as f32;
    let dy = rng.range(-1.5, 1.5) as f32;
    let thickness = rng.range(0.9, 1.6) as f32;
    let (sin, cos) = angle.sin_cos();
    let center = SIZE as f32 / 2.0;
    let to_px = |p: (f32, f32)| -> (f32, f32) {
        // Glyph box → pixel coords, rotated and scaled around the center.
        let gx = (p.0 - 0.5) * 22.0 * scale;
        let gy = (p.1 - 0.5) * 22.0 * scale;
        (
            center + gx * cos - gy * sin + dx,
            center + gx * sin + gy * cos + dy,
        )
    };
    let segs_px: Vec<((f32, f32), (f32, f32))> =
        segs.iter().map(|&(a, b)| (to_px(a), to_px(b))).collect();

    let mut img = vec![0.0f32; SIZE * SIZE];
    for (y, row) in img.chunks_mut(SIZE).enumerate() {
        for (x, px) in row.iter_mut().enumerate() {
            let p = (x as f32 + 0.5, y as f32 + 0.5);
            let mut d = f32::INFINITY;
            for &(a, b) in &segs_px {
                d = d.min(dist_point_segment(p, a, b));
            }
            // Soft pen: full ink inside `thickness`, smooth falloff after.
            let v = (1.0 - (d - thickness).max(0.0) / 1.2).clamp(0.0, 1.0);
            *px = v;
        }
    }
    // Pixel noise + occasional dead pixels, as scanner-like corruption.
    for px in &mut img {
        let noise = rng.range(-0.06, 0.06) as f32;
        *px = (*px + noise).clamp(0.0, 1.0);
    }
    img
}

fn dist_point_segment(p: (f32, f32), a: (f32, f32), b: (f32, f32)) -> f32 {
    let (px, py) = (p.0 - a.0, p.1 - a.1);
    let (vx, vy) = (b.0 - a.0, b.1 - a.1);
    let len2 = vx * vx + vy * vy;
    let t = if len2 > 0.0 { ((px * vx + py * vy) / len2).clamp(0.0, 1.0) } else { 0.0 };
    let (cx, cy) = (a.0 + t * vx - p.0, a.1 + t * vy - p.1);
    (cx * cx + cy * cy).sqrt()
}

/// Generate `n` samples with round-robin class balance.
pub fn generate(n: usize, seed: u64) -> Dataset {
    let mut rng = Pcg32::new(seed);
    let mut inputs = Matrix::zeros(n, SIZE * SIZE);
    let mut labels = Vec::with_capacity(n);
    // Shuffled class sequence so mini-batches are mixed.
    let mut classes: Vec<usize> = (0..n).map(|i| i % 10).collect();
    rng.shuffle(&mut classes);
    for (i, &digit) in classes.iter().enumerate() {
        let img = render_digit(digit, &mut rng);
        inputs.data[i * SIZE * SIZE..(i + 1) * SIZE * SIZE].copy_from_slice(&img);
        labels.push(digit);
    }
    Dataset { inputs, labels, classes: 10, source: "synthetic".into() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_shape_and_range() {
        let mut rng = Pcg32::new(0);
        for d in 0..10 {
            let img = render_digit(d, &mut rng);
            assert_eq!(img.len(), 784);
            assert!(img.iter().all(|&v| (0.0..=1.0).contains(&v)));
            // Some ink, not all ink.
            let ink: f32 = img.iter().sum();
            assert!(ink > 10.0 && ink < 500.0, "digit {d} ink {ink}");
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let a = generate(20, 42);
        let b = generate(20, 42);
        assert_eq!(a.inputs.data, b.inputs.data);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn different_seeds_differ() {
        let a = generate(10, 1);
        let b = generate(10, 2);
        assert_ne!(a.inputs.data, b.inputs.data);
    }

    #[test]
    fn classes_balanced() {
        let ds = generate(100, 7);
        for c in 0..10 {
            let count = ds.labels.iter().filter(|&&l| l == c).count();
            assert_eq!(count, 10, "class {c}");
        }
    }

    #[test]
    fn same_class_samples_vary() {
        let mut rng = Pcg32::new(9);
        let a = render_digit(3, &mut rng);
        let b = render_digit(3, &mut rng);
        assert_ne!(a, b);
        // But they should still overlap substantially (same skeleton):
        let dot: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!(dot > 5.0);
    }

    #[test]
    fn digits_are_separable_by_template_matching() {
        // Nearest-mean classification on clean renders should beat 60% —
        // sanity that classes are actually distinguishable.
        let train = generate(200, 3);
        let test = generate(50, 4);
        let d = 784;
        let mut means = vec![vec![0.0f32; d]; 10];
        let mut counts = [0usize; 10];
        for (i, &l) in train.labels.iter().enumerate() {
            for (m, &v) in means[l].iter_mut().zip(train.inputs.row(i)) {
                *m += v;
            }
            counts[l] += 1;
        }
        for (m, &c) in means.iter_mut().zip(&counts) {
            for v in m.iter_mut() {
                *v /= c as f32;
            }
        }
        let mut correct = 0;
        for (i, &l) in test.labels.iter().enumerate() {
            let row = test.inputs.row(i);
            let best = (0..10)
                .min_by(|&a, &b| {
                    let da: f32 = means[a].iter().zip(row).map(|(m, v)| (m - v).powi(2)).sum();
                    let db: f32 = means[b].iter().zip(row).map(|(m, v)| (m - v).powi(2)).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best == l {
                correct += 1;
            }
        }
        assert!(correct >= 30, "template matching got {correct}/50");
    }
}
