//! Mini-batch iteration helpers shared by training and the serving
//! benches (request generators draw samples through these).

use super::Dataset;
use crate::nn::tensor::Matrix;
use crate::util::rng::Pcg32;

/// Copy the rows at `idx` into a fresh `len(idx) × d` matrix.
pub fn gather(inputs: &Matrix, idx: &[usize]) -> Matrix {
    let d = inputs.cols;
    let mut out = Matrix::zeros(idx.len(), d);
    for (bi, &si) in idx.iter().enumerate() {
        out.data[bi * d..(bi + 1) * d].copy_from_slice(inputs.row(si));
    }
    out
}

/// Iterator over shuffled mini-batches of `(inputs, labels)`.
pub struct BatchIter<'a> {
    dataset: &'a Dataset,
    order: Vec<usize>,
    pos: usize,
    batch_size: usize,
}

impl<'a> BatchIter<'a> {
    pub fn new(dataset: &'a Dataset, batch_size: usize, rng: &mut Pcg32) -> Self {
        assert!(batch_size > 0);
        let mut order: Vec<usize> = (0..dataset.len()).collect();
        rng.shuffle(&mut order);
        BatchIter { dataset, order, pos: 0, batch_size }
    }
}

impl<'a> Iterator for BatchIter<'a> {
    type Item = (Matrix, Vec<usize>);

    fn next(&mut self) -> Option<Self::Item> {
        if self.pos >= self.order.len() {
            return None;
        }
        let end = (self.pos + self.batch_size).min(self.order.len());
        let idx = &self.order[self.pos..end];
        let x = gather(&self.dataset.inputs, idx);
        let y = idx.iter().map(|&i| self.dataset.labels[i]).collect();
        self.pos = end;
        Some((x, y))
    }
}

/// Infinite sampler of single rows (used by the serving workload
/// generator to draw request payloads).
pub struct SampleStream<'a> {
    dataset: &'a Dataset,
    rng: Pcg32,
}

impl<'a> SampleStream<'a> {
    pub fn new(dataset: &'a Dataset, seed: u64) -> Self {
        SampleStream { dataset, rng: Pcg32::new(seed) }
    }

    pub fn next_sample(&mut self) -> (Vec<f32>, usize) {
        let i = self.rng.index(self.dataset.len());
        (self.dataset.inputs.row(i).to_vec(), self.dataset.labels[i])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::generate;

    #[test]
    fn batches_cover_dataset_once() {
        let ds = generate(25, 0);
        let mut rng = Pcg32::new(1);
        let mut seen = 0;
        let mut last_batch = 0;
        for (x, y) in BatchIter::new(&ds, 8, &mut rng) {
            assert_eq!(x.rows, y.len());
            seen += y.len();
            last_batch = y.len();
        }
        assert_eq!(seen, 25);
        assert_eq!(last_batch, 1); // 25 = 3×8 + 1
    }

    #[test]
    fn gather_copies_rows() {
        let ds = generate(10, 0);
        let g = gather(&ds.inputs, &[3, 7]);
        assert_eq!(g.row(0), ds.inputs.row(3));
        assert_eq!(g.row(1), ds.inputs.row(7));
    }

    #[test]
    fn sample_stream_draws_valid_rows() {
        let ds = generate(10, 0);
        let mut s = SampleStream::new(&ds, 2);
        for _ in 0..20 {
            let (x, y) = s.next_sample();
            assert_eq!(x.len(), 784);
            assert!(y < 10);
        }
    }
}
