//! Dataset pipeline (§4.3 of the paper): MNIST if real idx files are
//! available (`MNIST_DIR`), otherwise a deterministic synthetic 28×28
//! digit set with the same dimensionality and class structure — see
//! DESIGN.md §5 (Substitutions) for why this preserves the experiments'
//! behaviour.

pub mod batch;
pub mod mnist;
pub mod synth;

use crate::nn::tensor::Matrix;

/// A labeled image-classification dataset: flattened inputs in `[0, 1]`
/// plus integer labels.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// `n × d` inputs (d = 784 for 28×28 digits).
    pub inputs: Matrix,
    pub labels: Vec<usize>,
    pub classes: usize,
    /// Provenance tag: `"mnist"` or `"synthetic"`.
    pub source: String,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Split off the last `n` samples as a held-out set.
    pub fn split_holdout(mut self, n: usize) -> (Dataset, Dataset) {
        assert!(n < self.len(), "holdout {n} >= dataset {}", self.len());
        let train_n = self.len() - n;
        let d = self.inputs.cols;
        let test_inputs =
            Matrix::from_vec(n, d, self.inputs.data.split_off(train_n * d));
        let test_labels = self.labels.split_off(train_n);
        self.inputs.rows = train_n;
        let test = Dataset {
            inputs: test_inputs,
            labels: test_labels,
            classes: self.classes,
            source: self.source.clone(),
        };
        (self, test)
    }
}

/// Load the experiment dataset: real MNIST when `MNIST_DIR` points at the
/// idx files, synthetic otherwise. `n_train`/`n_test` cap the sizes so
/// benches stay fast.
pub fn load_digits(n_train: usize, n_test: usize, seed: u64) -> (Dataset, Dataset) {
    if let Ok(dir) = std::env::var("MNIST_DIR") {
        match mnist::load_mnist(std::path::Path::new(&dir), n_train, n_test) {
            Ok(pair) => return pair,
            Err(e) => eprintln!("MNIST_DIR set but load failed ({e}); falling back to synthetic"),
        }
    }
    let train = synth::generate(n_train, seed);
    let test = synth::generate(n_test, seed ^ 0x5EED_7E57);
    (train, test)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_holdout_partitions() {
        let ds = synth::generate(50, 1);
        let (train, test) = ds.split_holdout(10);
        assert_eq!(train.len(), 40);
        assert_eq!(test.len(), 10);
        assert_eq!(train.inputs.rows, 40);
        assert_eq!(test.inputs.rows, 10);
        assert_eq!(test.inputs.cols, 784);
    }

    #[test]
    fn load_digits_returns_requested_sizes() {
        let (train, test) = load_digits(32, 8, 3);
        assert_eq!(train.len(), 32);
        assert_eq!(test.len(), 8);
    }
}
