//! Cycle-accurate model of the paper's FPGA accelerator (§3.1, Fig 1/2):
//! a dual-clock input buffer feeding a pipelined array of shift-add
//! processing units, with a sigmoid LUT between layers and an
//! activity-based power model on top.
//!
//! The simulator is *exact* under its microarchitectural model — it
//! derives per-row start/finish times analytically from the clock ratio
//! and buffer state rather than ticking every cycle, so Table-I runs
//! finish in milliseconds while still reporting the same cycle counts a
//! tick-by-tick simulation of the model would (a test in [`pipeline`]
//! cross-checks a small tick-level reference).
//!
//! Two outputs per inference:
//! * **numbers** — bit-accurate fixed-point shift-add arithmetic
//!   ([`pu`]), so the accelerator's accuracy can be measured end-to-end;
//! * **events** — cycle and primitive-operation counts ([`stats`]),
//!   which [`power`] converts to energy/power and the Table-I bench
//!   converts to time-per-sample at the configured `clk_compute`.

pub mod accelerator;
pub mod clock;
pub mod input_buffer;
pub mod pipeline;
pub mod power;
pub mod pu;
pub mod stats;
pub mod tick_ref;
pub mod verilog;

pub use accelerator::{AccelConfig, Accelerator};
pub use stats::CycleStats;
