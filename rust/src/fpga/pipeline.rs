//! The pipelined matrix-multiply engine of §3.1 / Fig 2.
//!
//! For `W (m×n) · d (n×1)`: the weight matrix is decomposed into rows
//! `w₁ … w_m`, each concatenated with `d` into a reorganized row and
//! streamed through the input buffer to an array of `P` first-level PUs.
//! Row `i` starts one compute cycle behind row `i-1` (the paper's
//! pipeline stagger); a PU executes `lanes` MACs per cycle, so row `i`'s
//! dot product emerges `ceil(n/lanes) + depth` cycles after it starts.
//! Outputs concatenate into `W · d`. Two schedules exist: the literal
//! §3.1 *streaming* dataflow (reorganized rows re-loaded per sample) and
//! the *weight-resident* serving mode — see [`PipelineConfig`].
//!
//! The schedule is computed row-analytically (each row's start time is
//! the max of its buffer-availability, its PU's free time, and the
//! stagger constraint) — exact under the model, no per-cycle ticking.

use super::clock::ClockConfig;
use super::input_buffer::InputBuffer;
use super::pu::{dot_shift_add, quantize_data};
use super::stats::CycleStats;
use crate::quant::spx::SpxTensor;

/// Pipeline micro-architecture parameters.
#[derive(Debug, Clone, Copy)]
pub struct PipelineConfig {
    pub clocks: ClockConfig,
    /// Number of first-level PUs (`P`). Rows are assigned round-robin.
    pub num_pus: usize,
    /// Input-buffer capacity in reorganized rows.
    pub buffer_capacity_rows: usize,
    /// Extra output-stage latency in cycles (shift/add-tree/rescale
    /// registers).
    pub pipeline_depth: u32,
    /// Parallel MAC lanes inside each PU — a row finishes in
    /// `ceil(n / lanes)` cycles. The paper's 1.6 µs/sample implies a
    /// multi-lane array (101k MACs in ~200 cycles); lanes = 8 with 128
    /// PUs is a 1024-MAC fabric, plausible on a mid-size part.
    pub lanes: usize,
    /// Weight residency. `true`: weight rows stay in on-chip SRAM
    /// across samples and only the data vector streams per inference —
    /// the steady-state serving mode, and the only reading of §3.1
    /// consistent with Table I's 1.6 µs (re-streaming 200 KiB of
    /// weights per sample cannot). `false`: every sample streams full
    /// reorganized rows (`wᵢ ‖ d`) through the input buffer — the
    /// literal Fig 1/2 dataflow, used by the §3.1 ablation study.
    pub weight_resident: bool,
}

impl PipelineConfig {
    /// The Table-I device: weight-resident, 8-lane PUs.
    pub fn default_fpga() -> Self {
        PipelineConfig {
            clocks: ClockConfig::default_fpga(),
            num_pus: 128,
            buffer_capacity_rows: 32,
            pipeline_depth: 3,
            lanes: 8,
            weight_resident: true,
        }
    }

    /// The literal §3.1 streaming dataflow (Fig 1/2): single-lane PUs,
    /// reorganized rows re-loaded per sample. The pipeline-ablation
    /// experiment studies this configuration.
    pub fn streaming() -> Self {
        PipelineConfig {
            clocks: ClockConfig::default_fpga(),
            num_pus: 128,
            buffer_capacity_rows: 32,
            pipeline_depth: 3,
            lanes: 1,
            weight_resident: false,
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        self.clocks.validate()?;
        if self.num_pus == 0 {
            return Err("num_pus must be positive".into());
        }
        if self.buffer_capacity_rows == 0 {
            return Err("buffer capacity must be positive".into());
        }
        if self.lanes == 0 {
            return Err("lanes must be positive".into());
        }
        Ok(())
    }
}

/// Result of one `W · d` pass.
#[derive(Debug, Clone)]
pub struct LayerRun {
    /// The m dot products (bit-accurate shift-add arithmetic).
    pub outputs: Vec<f32>,
    pub stats: CycleStats,
}

/// Execute `W · d` through the pipelined engine.
///
/// * `w` — SPx-quantized `m × n` weight matrix.
/// * `d` — data vector (length n), values scaled by `d_scale` into Q1.15.
pub fn run_matvec(
    w: &SpxTensor,
    d: &[f32],
    d_scale: f32,
    cfg: &PipelineConfig,
) -> LayerRun {
    assert_eq!(w.shape.len(), 2, "weights must be a matrix");
    let n = w.shape[1];
    assert_eq!(d.len(), n, "data length {} vs weight cols {n}", d.len());
    cfg.validate().expect("invalid pipeline config");
    if cfg.weight_resident {
        run_matvec_resident(w, d, d_scale, cfg)
    } else {
        run_matvec_streaming(w, d, d_scale, cfg)
    }
}

/// Streaming schedule: every sample loads full reorganized rows.
fn run_matvec_streaming(
    w: &SpxTensor,
    d: &[f32],
    d_scale: f32,
    cfg: &PipelineConfig,
) -> LayerRun {
    let (m, n) = (w.shape[0], w.shape[1]);
    let mut stats = CycleStats::default();
    let row_words = 2 * n; // reorganized row = wᵢ ‖ d
    let mut buffer = InputBuffer::new(&cfg.clocks, cfg.buffer_capacity_rows, row_words);

    // RAM traffic: m weight rows + the data vector read once by the
    // preprocessor; the buffer then holds m full reorganized rows.
    stats.ram_reads += (m * n + n) as u64;
    stats.buffer_writes += (m * row_words) as u64;
    stats.buffer_reads += (m * row_words) as u64;

    let d_fixed = quantize_data(d, d_scale);
    let busy_cycles = (n as f64 / cfg.lanes as f64).ceil();

    let mut pu_free = vec![0.0f64; cfg.num_pus];
    let mut prev_start = f64::NEG_INFINITY;
    let mut outputs = Vec::with_capacity(m);
    let mut last_finish = 0.0f64;
    let mut stall = 0.0f64;

    for r in 0..m {
        let avail = buffer.load_next_row();
        let p = r % cfg.num_pus;
        // Stagger: one cycle behind the previous row; PU must be free.
        let ready = pu_free[p].max(if r == 0 { 0.0 } else { prev_start + 1.0 });
        let start = ready.max(avail);
        stall += (avail - ready).max(0.0);
        let busy_until = start + busy_cycles;
        let finish = busy_until + cfg.pipeline_depth as f64;
        pu_free[p] = busy_until;
        prev_start = start;
        last_finish = last_finish.max(finish);
        buffer.release_row(r, busy_until);

        outputs.push(dot_shift_add(w, r, &d_fixed, d_scale, &mut stats));
    }

    stats.compute_cycles = last_finish.ceil() as u64;
    stats.stall_cycles = stall.ceil() as u64;
    stats.buffer_peak_rows = buffer.peak_occupancy();
    LayerRun { outputs, stats }
}

/// Weight-resident schedule: weights live in on-chip SRAM; only the
/// data vector crosses the input buffer per sample, so all rows become
/// eligible as soon as the `n`-word data transfer lands.
fn run_matvec_resident(
    w: &SpxTensor,
    d: &[f32],
    d_scale: f32,
    cfg: &PipelineConfig,
) -> LayerRun {
    let (m, n) = (w.shape[0], w.shape[1]);
    let mut stats = CycleStats::default();

    // Per-sample traffic: the data vector only. (The one-time weight
    // fill is amortized across the deployment and not charged here —
    // DESIGN.md §5 documents this accounting.)
    stats.ram_reads += n as u64;
    stats.buffer_writes += n as u64;
    // PUs read weights from their SRAM banks and data from the buffer.
    stats.buffer_reads += (m * n + n) as u64;

    let data_avail = cfg.clocks.load_finish_cycle(n as u64);
    let d_fixed = quantize_data(d, d_scale);
    let busy_cycles = (n as f64 / cfg.lanes as f64).ceil();

    let mut pu_free = vec![0.0f64; cfg.num_pus];
    let mut prev_start = f64::NEG_INFINITY;
    let mut outputs = Vec::with_capacity(m);
    let mut last_finish = 0.0f64;
    let mut stall = 0.0f64;

    for r in 0..m {
        let p = r % cfg.num_pus;
        let ready = pu_free[p].max(if r == 0 { 0.0 } else { prev_start + 1.0 });
        let start = ready.max(data_avail);
        stall += (data_avail - ready).max(0.0);
        let busy_until = start + busy_cycles;
        let finish = busy_until + cfg.pipeline_depth as f64;
        pu_free[p] = busy_until;
        prev_start = start;
        last_finish = last_finish.max(finish);

        outputs.push(dot_shift_add(w, r, &d_fixed, d_scale, &mut stats));
    }

    stats.compute_cycles = last_finish.ceil() as u64;
    stats.stall_cycles = stall.ceil() as u64;
    stats.buffer_peak_rows = 1;
    LayerRun { outputs, stats }
}

/// Reference (non-pipelined) schedule for the ablation bench E3: rows
/// are processed strictly sequentially by a single PU, and every row's
/// load waits for the previous row's compute to finish (no
/// load/compute decoupling — the design §3.1 replaces).
pub fn run_matvec_unpipelined(
    w: &SpxTensor,
    d: &[f32],
    d_scale: f32,
    cfg: &PipelineConfig,
) -> LayerRun {
    let (m, n) = (w.shape[0], w.shape[1]);
    assert_eq!(d.len(), n);
    let mut stats = CycleStats::default();
    let row_words = 2 * n;
    stats.ram_reads += (m * n + n) as u64;
    stats.buffer_writes += (m * row_words) as u64;
    stats.buffer_reads += (m * row_words) as u64;

    let d_fixed = quantize_data(d, d_scale);
    let buffer = InputBuffer::new(&cfg.clocks, 1, row_words);
    let load_cycles = buffer.row_load_compute_cycles();
    let mut t = 0.0f64;
    let mut outputs = Vec::with_capacity(m);
    for r in 0..m {
        t += load_cycles; // serialized load
        t += n as f64 + cfg.pipeline_depth as f64; // then compute
        outputs.push(dot_shift_add(w, r, &d_fixed, d_scale, &mut stats));
    }
    stats.compute_cycles = t.ceil() as u64;
    stats.stall_cycles = (m as f64 * load_cycles).ceil() as u64;
    stats.buffer_peak_rows = 1;
    LayerRun { outputs, stats }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::spx::SpxConfig;
    use crate::quant::Calibration;
    use crate::util::check::{assert_allclose, property};
    use crate::util::rng::Pcg32;

    fn quantized(m: usize, n: usize, rng: &mut Pcg32) -> SpxTensor {
        let data: Vec<f32> = (0..m * n).map(|_| rng.normal() as f32 * 0.4).collect();
        SpxTensor::encode(&SpxConfig::sp2(5), &data, &[m, n], Calibration::MaxAbs)
    }

    fn fast_load_cfg(num_pus: usize) -> PipelineConfig {
        PipelineConfig {
            clocks: ClockConfig {
                clk_inbuff_mhz: 1000.0,
                clk_compute_mhz: 1.0,
                bandwidth_words: 4096,
            },
            num_pus,
            buffer_capacity_rows: 4096,
            pipeline_depth: 3,
            lanes: 1,
            weight_resident: false,
        }
    }

    #[test]
    fn classic_pipeline_formula_under_infinite_bandwidth() {
        // With loading effectively free and P ≥ m, the schedule is the
        // textbook pipeline: total = (m-1) stagger + n MACs + depth,
        // plus the sub-cycle first-row load latency that rounds up once.
        let mut rng = Pcg32::new(1);
        let (m, n) = (16, 32);
        let w = quantized(m, n, &mut rng);
        let d: Vec<f32> = (0..n).map(|_| rng.uniform() as f32).collect();
        let run = run_matvec(&w, &d, 1.0, &fast_load_cfg(m));
        assert_eq!(
            run.stats.compute_cycles,
            (m - 1 + n + 3) as u64 + 1,
            "stats: {:?}",
            run.stats
        );
        // The only stall is waiting for the very first row to land.
        assert!(run.stats.stall_cycles <= 1);
    }

    #[test]
    fn outputs_match_pu_reference() {
        property("pipeline outputs == direct dot products", 16, |rng| {
            let (m, n) = (1 + rng.index(12), 1 + rng.index(24));
            let w = quantized(m, n, rng);
            let d: Vec<f32> = (0..n).map(|_| rng.uniform() as f32).collect();
            let run = run_matvec(&w, &d, 1.0, &PipelineConfig::default_fpga());
            let d_fixed = quantize_data(&d, 1.0);
            let mut s = CycleStats::default();
            let expect: Vec<f32> =
                (0..m).map(|r| dot_shift_add(&w, r, &d_fixed, 1.0, &mut s)).collect();
            assert_allclose(&run.outputs, &expect, 1e-7, 1e-6);
        });
    }

    #[test]
    fn slow_loading_stalls_pipeline() {
        let mut rng = Pcg32::new(2);
        let (m, n) = (32, 64);
        let w = quantized(m, n, &mut rng);
        let d: Vec<f32> = (0..n).map(|_| rng.uniform() as f32).collect();
        let slow = PipelineConfig {
            clocks: ClockConfig {
                clk_inbuff_mhz: 1.0,
                clk_compute_mhz: 100.0,
                bandwidth_words: 8,
            },
            num_pus: m,
            buffer_capacity_rows: 64,
            pipeline_depth: 3,
            lanes: 1,
            weight_resident: false,
        };
        let run = run_matvec(&w, &d, 1.0, &slow);
        assert!(run.stats.stall_cycles > 0, "expected starvation: {:?}", run.stats);
        // Load-bound: total ≈ m rows × row-load-time.
        let per_row = 2.0 * n as f64 / 8.0 * 100.0; // inbuff cycles × ratio
        assert!(run.stats.compute_cycles as f64 >= m as f64 * per_row * 0.9);
    }

    #[test]
    fn faster_load_clock_never_hurts() {
        property("monotone in load clock", 8, |rng| {
            let (m, n) = (8 + rng.index(24), 8 + rng.index(56));
            let w = quantized(m, n, rng);
            let d: Vec<f32> = (0..n).map(|_| rng.uniform() as f32).collect();
            let mut last = u64::MAX;
            for inbuff in [5.0, 20.0, 80.0, 320.0] {
                let cfg = PipelineConfig {
                    clocks: ClockConfig {
                        clk_inbuff_mhz: inbuff,
                        clk_compute_mhz: 100.0,
                        bandwidth_words: 16,
                    },
                    num_pus: 16,
                    buffer_capacity_rows: 16,
                    pipeline_depth: 3,
                    lanes: 1,
                    weight_resident: false,
                };
                let run = run_matvec(&w, &d, 1.0, &cfg);
                assert!(
                    run.stats.compute_cycles <= last,
                    "cycles grew when load clock rose to {inbuff} MHz"
                );
                last = run.stats.compute_cycles;
            }
        });
    }

    #[test]
    fn bigger_buffer_never_hurts() {
        property("monotone in buffer capacity", 8, |rng| {
            let (m, n) = (16 + rng.index(16), 8 + rng.index(24));
            let w = quantized(m, n, rng);
            let d: Vec<f32> = (0..n).map(|_| rng.uniform() as f32).collect();
            let mut last = u64::MAX;
            for cap in [1usize, 2, 8, 64] {
                let cfg = PipelineConfig {
                    clocks: ClockConfig {
                        clk_inbuff_mhz: 30.0,
                        clk_compute_mhz: 100.0,
                        bandwidth_words: 8,
                    },
                    num_pus: 8,
                    buffer_capacity_rows: cap,
                    pipeline_depth: 3,
                    lanes: 1,
                    weight_resident: false,
                };
                let run = run_matvec(&w, &d, 1.0, &cfg);
                assert!(run.stats.compute_cycles <= last, "cap {cap} worsened schedule");
                last = run.stats.compute_cycles;
            }
        });
    }

    #[test]
    fn pipelined_beats_unpipelined() {
        // E3's headline: the §3.1 design vs the serialized baseline.
        let mut rng = Pcg32::new(3);
        let (m, n) = (128, 784);
        let w = quantized(m, n, &mut rng);
        let d: Vec<f32> = (0..n).map(|_| rng.uniform() as f32).collect();
        let cfg = PipelineConfig::default_fpga();
        let piped = run_matvec(&w, &d, 1.0, &cfg);
        let serial = run_matvec_unpipelined(&w, &d, 1.0, &cfg);
        assert!(
            piped.stats.compute_cycles * 4 < serial.stats.compute_cycles,
            "pipelined {} vs serial {}",
            piped.stats.compute_cycles,
            serial.stats.compute_cycles
        );
        // Same arithmetic, same answers.
        assert_allclose(&piped.outputs, &serial.outputs, 1e-7, 1e-6);
    }

    #[test]
    fn buffer_peak_bounded_by_capacity_plus_transfer() {
        let mut rng = Pcg32::new(4);
        let (m, n) = (64, 32);
        let w = quantized(m, n, &mut rng);
        let d: Vec<f32> = (0..n).map(|_| rng.uniform() as f32).collect();
        let cfg = PipelineConfig {
            clocks: ClockConfig {
                clk_inbuff_mhz: 200.0,
                clk_compute_mhz: 100.0,
                bandwidth_words: 64,
            },
            num_pus: 4,
            buffer_capacity_rows: 8,
            pipeline_depth: 3,
            lanes: 1,
            weight_resident: false,
        };
        let run = run_matvec(&w, &d, 1.0, &cfg);
        assert!(
            run.stats.buffer_peak_rows <= 9,
            "peak {} exceeds capacity+in-flight",
            run.stats.buffer_peak_rows
        );
    }
}
