//! The two asynchronous clock domains of §3.1: `clk_inbuff` paces RAM →
//! input-buffer loading, `clk_compute` paces the PU pipeline. The paper's
//! feasibility argument — loading outruns computing despite a slower
//! load clock, because each load moves `bandwidth` words — is encoded in
//! [`ClockConfig::words_per_compute_cycle`].

/// Dual-clock configuration. Frequencies in MHz.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClockConfig {
    /// Input-buffer write clock (RAM side). The paper notes this is
    /// *slower* per cycle (e.g. >300 ns example) but wide.
    pub clk_inbuff_mhz: f64,
    /// PU compute clock.
    pub clk_compute_mhz: f64,
    /// Words transferred into the buffer per `clk_inbuff` cycle
    /// (the RAM–buffer bandwidth, in elements).
    pub bandwidth_words: u32,
}

impl ClockConfig {
    /// APEX-class defaults: 150 MHz compute, 75 MHz load clock moving
    /// 256 words/cycle — a wide internal BRAM port, exactly the §3.1
    /// argument: the load *clock* is slower (its period is "necessarily
    /// larger than the computing clock-cycle") but each load cycle moves
    /// a whole burst, so aggregate loading outruns computing.
    pub fn default_fpga() -> Self {
        ClockConfig { clk_inbuff_mhz: 75.0, clk_compute_mhz: 150.0, bandwidth_words: 256 }
    }

    pub fn compute_period_ns(&self) -> f64 {
        1e3 / self.clk_compute_mhz
    }

    pub fn inbuff_period_ns(&self) -> f64 {
        1e3 / self.clk_inbuff_mhz
    }

    /// Effective load throughput measured in words per *compute* cycle —
    /// the number that must exceed the pipeline's consumption rate for
    /// stall-free operation.
    pub fn words_per_compute_cycle(&self) -> f64 {
        self.bandwidth_words as f64 * self.clk_inbuff_mhz / self.clk_compute_mhz
    }

    /// Convert a compute-cycle count to seconds.
    pub fn cycles_to_seconds(&self, cycles: u64) -> f64 {
        cycles as f64 * self.compute_period_ns() * 1e-9
    }

    /// Compute cycle (fractional) at which `words` words have finished
    /// loading, assuming loading starts at compute-cycle 0 and moves
    /// `bandwidth_words` per inbuff cycle (a word is visible only at the
    /// inbuff clock edge that completes it).
    pub fn load_finish_cycle(&self, words: u64) -> f64 {
        let inbuff_cycles = (words as f64 / self.bandwidth_words as f64).ceil();
        inbuff_cycles * self.clk_compute_mhz / self.clk_inbuff_mhz
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.clk_inbuff_mhz <= 0.0 || self.clk_compute_mhz <= 0.0 {
            return Err("clock frequencies must be positive".into());
        }
        if self.bandwidth_words == 0 {
            return Err("bandwidth_words must be positive".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_validates() {
        ClockConfig::default_fpga().validate().unwrap();
    }

    #[test]
    fn periods() {
        let c = ClockConfig { clk_inbuff_mhz: 100.0, clk_compute_mhz: 200.0, bandwidth_words: 4 };
        assert!((c.compute_period_ns() - 5.0).abs() < 1e-12);
        assert!((c.inbuff_period_ns() - 10.0).abs() < 1e-12);
    }

    #[test]
    fn words_per_compute_cycle_scales_with_ratio() {
        let c = ClockConfig { clk_inbuff_mhz: 50.0, clk_compute_mhz: 100.0, bandwidth_words: 8 };
        // 8 words every 2 compute cycles → 4 words/compute-cycle.
        assert!((c.words_per_compute_cycle() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn load_finish_cycle_edges() {
        let c = ClockConfig { clk_inbuff_mhz: 100.0, clk_compute_mhz: 100.0, bandwidth_words: 8 };
        // 8 words → exactly 1 inbuff cycle → compute cycle 1.
        assert!((c.load_finish_cycle(8) - 1.0).abs() < 1e-12);
        // 9 words → 2 inbuff cycles.
        assert!((c.load_finish_cycle(9) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn paper_example_load_slower_but_wider() {
        // The §3.1 example: load clock slower (>300 ns) than compute,
        // yet loading keeps up because of width. 3 MHz load × 256 words
        // vs 100 MHz compute consuming 1 word/PU-cycle × 2 PUs.
        let c = ClockConfig { clk_inbuff_mhz: 3.3, clk_compute_mhz: 100.0, bandwidth_words: 256 };
        assert!(c.words_per_compute_cycle() > 2.0);
    }

    #[test]
    fn cycles_to_seconds_roundtrip() {
        let c = ClockConfig { clk_inbuff_mhz: 50.0, clk_compute_mhz: 100.0, bandwidth_words: 8 };
        let s = c.cycles_to_seconds(100_000_000);
        assert!((s - 1.0).abs() < 1e-9); // 1e8 cycles at 100 MHz = 1 s.
    }
}
