//! Event and cycle counters shared by every stage of the simulator.
//! These are the raw material for both the Table-I time-per-sample
//! number (cycles ÷ clk_compute) and the power model (events × energy).

/// Aggregate counts for one or more simulated inferences.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CycleStats {
    /// Total compute-clock cycles from first load to last output.
    pub compute_cycles: u64,
    /// Cycles a PU spent waiting on the input buffer (starvation).
    pub stall_cycles: u64,
    /// Multiply-accumulate operations executed (one weight×data each).
    pub macs: u64,
    /// Barrel-shift operations (SPx terms: `x` per MAC).
    pub shifts: u64,
    /// Integer additions (term sums + accumulation).
    pub adds: u64,
    /// Full multiplications (only the per-output `α/max_sum · d_scale`
    /// rescale and bias path — the design's whole point is that MACs
    /// don't multiply).
    pub mults: u64,
    /// Sigmoid LUT lookups.
    pub lut_lookups: u64,
    /// Words read from external RAM.
    pub ram_reads: u64,
    /// Words written into the input buffer.
    pub buffer_writes: u64,
    /// Words read out of the input buffer by PUs.
    pub buffer_reads: u64,
    /// High-water mark of buffered rows (capacity sizing).
    pub buffer_peak_rows: u64,
}

impl CycleStats {
    /// Accumulate another stats block (sequential composition: cycles
    /// add; peak occupancy takes the max).
    pub fn merge(&mut self, other: &CycleStats) {
        self.compute_cycles += other.compute_cycles;
        self.stall_cycles += other.stall_cycles;
        self.macs += other.macs;
        self.shifts += other.shifts;
        self.adds += other.adds;
        self.mults += other.mults;
        self.lut_lookups += other.lut_lookups;
        self.ram_reads += other.ram_reads;
        self.buffer_writes += other.buffer_writes;
        self.buffer_reads += other.buffer_reads;
        self.buffer_peak_rows = self.buffer_peak_rows.max(other.buffer_peak_rows);
    }

    /// Sequential composition of `k` identical inferences: additive
    /// counters scale, peak occupancy does not. Every counter the
    /// simulator charges is data-independent (schedules are analytic in
    /// the shape; adds count *weight* sparsity, not data), so the batch
    /// paths ([`crate::fpga::accelerator::Accelerator::infer_batch`])
    /// report exactly what `k` sequential [`CycleStats::merge`]s of one
    /// sample's stats would.
    pub fn scaled(&self, k: u64) -> CycleStats {
        CycleStats {
            compute_cycles: self.compute_cycles * k,
            stall_cycles: self.stall_cycles * k,
            macs: self.macs * k,
            shifts: self.shifts * k,
            adds: self.adds * k,
            mults: self.mults * k,
            lut_lookups: self.lut_lookups * k,
            ram_reads: self.ram_reads * k,
            buffer_writes: self.buffer_writes * k,
            buffer_reads: self.buffer_reads * k,
            buffer_peak_rows: self.buffer_peak_rows,
        }
    }

    /// MACs per compute cycle — pipeline utilization (1.0 per PU is the
    /// roofline; reported per-array by dividing by the PU count).
    pub fn macs_per_cycle(&self) -> f64 {
        if self.compute_cycles == 0 {
            0.0
        } else {
            self.macs as f64 / self.compute_cycles as f64
        }
    }

    /// Fraction of cycles lost to buffer starvation.
    pub fn stall_fraction(&self) -> f64 {
        if self.compute_cycles == 0 {
            0.0
        } else {
            self.stall_cycles as f64 / self.compute_cycles as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_adds_and_maxes() {
        let mut a = CycleStats { compute_cycles: 10, buffer_peak_rows: 3, macs: 5, ..Default::default() };
        let b = CycleStats { compute_cycles: 7, buffer_peak_rows: 9, macs: 2, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.compute_cycles, 17);
        assert_eq!(a.macs, 7);
        assert_eq!(a.buffer_peak_rows, 9);
    }

    #[test]
    fn scaled_matches_repeated_merge() {
        let s = CycleStats {
            compute_cycles: 10,
            stall_cycles: 1,
            macs: 5,
            shifts: 12,
            adds: 9,
            mults: 2,
            lut_lookups: 3,
            ram_reads: 7,
            buffer_writes: 6,
            buffer_reads: 8,
            buffer_peak_rows: 4,
        };
        let mut merged = CycleStats::default();
        for _ in 0..5 {
            merged.merge(&s);
        }
        assert_eq!(s.scaled(5), merged);
    }

    #[test]
    fn utilization_zero_when_idle() {
        let s = CycleStats::default();
        assert_eq!(s.macs_per_cycle(), 0.0);
        assert_eq!(s.stall_fraction(), 0.0);
    }

    #[test]
    fn utilization_basic() {
        let s = CycleStats { compute_cycles: 100, macs: 50, stall_cycles: 25, ..Default::default() };
        assert_eq!(s.macs_per_cycle(), 0.5);
        assert_eq!(s.stall_fraction(), 0.25);
    }
}
