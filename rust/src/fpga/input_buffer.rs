//! The input buffer of Fig 1: written from RAM at `clk_inbuff`, read by
//! the PU pipeline at `clk_compute`, with bounded capacity and therefore
//! backpressure on the loader.
//!
//! Loading is modeled at row granularity: one *reorganized row*
//! (`wᵢ ‖ d`, `2n` words) takes `ceil(2n / bandwidth)` inbuff cycles,
//! and a row becomes visible to the PUs at the inbuff clock edge that
//! completes it (clock-domain crossing — a word cannot be consumed
//! mid-transfer). When the buffer already holds `capacity_rows` rows the
//! loader stalls until the pipeline releases one.
//!
//! All times are expressed in (fractional) compute-clock cycles so the
//! pipeline can compare them directly with PU busy times.

use super::clock::ClockConfig;

/// Loader + occupancy model for one layer's row stream.
#[derive(Debug)]
pub struct InputBuffer {
    /// Compute cycles per inbuff cycle (`f_compute / f_inbuff`).
    ratio: f64,
    /// Inbuff cycles needed to transfer one row.
    load_cycles_per_row: u64,
    capacity_rows: usize,
    /// Loader's next-free time (compute cycles).
    loader_free: f64,
    /// Row availability times, in row order.
    avail: Vec<f64>,
    /// Row release times (set by the pipeline as PUs finish), row order.
    released: Vec<f64>,
}

impl InputBuffer {
    /// `row_words` is the reorganized-row width `2n`.
    pub fn new(clocks: &ClockConfig, capacity_rows: usize, row_words: usize) -> Self {
        assert!(capacity_rows >= 1, "buffer must hold at least one row");
        assert!(row_words >= 1);
        let load_cycles_per_row =
            (row_words as u64).div_ceil(clocks.bandwidth_words as u64);
        InputBuffer {
            ratio: clocks.clk_compute_mhz / clocks.clk_inbuff_mhz,
            load_cycles_per_row,
            capacity_rows,
            loader_free: 0.0,
            avail: Vec::new(),
            released: Vec::new(),
        }
    }

    /// Compute cycles one row spends in transfer.
    pub fn row_load_compute_cycles(&self) -> f64 {
        self.load_cycles_per_row as f64 * self.ratio
    }

    /// Schedule the load of the next row (row index = number of prior
    /// calls). Returns the time the row becomes available to a PU.
    ///
    /// Backpressure: loading row `r` cannot *start* before row
    /// `r - capacity` has been released (its slot must be free).
    pub fn load_next_row(&mut self) -> f64 {
        let r = self.avail.len();
        let gate = if r >= self.capacity_rows {
            *self
                .released
                .get(r - self.capacity_rows)
                .expect("pipeline must release rows before loading capacity+r")
        } else {
            0.0
        };
        let begin = self.loader_free.max(gate);
        // Align the start to the next inbuff clock edge.
        let begin_edge = (begin / self.ratio).ceil();
        let done = (begin_edge + self.load_cycles_per_row as f64) * self.ratio;
        self.loader_free = done;
        self.avail.push(done);
        done
    }

    /// The pipeline reports that row `r` has been fully consumed at `t`.
    /// Must be called in row order.
    pub fn release_row(&mut self, r: usize, t: f64) {
        assert_eq!(r, self.released.len(), "releases must be in row order");
        debug_assert!(t >= self.avail[r], "released before available");
        self.released.push(t);
    }

    /// High-water mark of simultaneously buffered rows: row `r` occupies
    /// the buffer in `[avail[r], released[r])` (transfer slots counted at
    /// completion; in-flight transfer occupies its slot too via the gate).
    pub fn peak_occupancy(&self) -> u64 {
        let mut peak = 0u64;
        // Two-pointer sweep: at each availability event, count rows not
        // yet released.
        let mut rel_ptr = 0usize;
        for (r, &a) in self.avail.iter().enumerate() {
            while rel_ptr < self.released.len() && self.released[rel_ptr] <= a {
                rel_ptr += 1;
            }
            peak = peak.max((r + 1 - rel_ptr) as u64);
        }
        peak
    }

    pub fn rows_loaded(&self) -> usize {
        self.avail.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn clocks(inbuff: f64, compute: f64, bw: u32) -> ClockConfig {
        ClockConfig { clk_inbuff_mhz: inbuff, clk_compute_mhz: compute, bandwidth_words: bw }
    }

    #[test]
    fn first_row_arrives_after_transfer_time() {
        // 16-word rows, 8 words/cycle, equal clocks → 2 cycles per row.
        let c = clocks(100.0, 100.0, 8);
        let mut buf = InputBuffer::new(&c, 4, 16);
        assert_eq!(buf.load_next_row(), 2.0);
        assert_eq!(buf.load_next_row(), 4.0);
    }

    #[test]
    fn clock_ratio_scales_availability() {
        // Load clock at half the compute clock: 2 inbuff cycles = 4
        // compute cycles.
        let c = clocks(50.0, 100.0, 8);
        let mut buf = InputBuffer::new(&c, 4, 16);
        assert_eq!(buf.load_next_row(), 4.0);
    }

    #[test]
    fn backpressure_gates_on_release() {
        let c = clocks(100.0, 100.0, 16);
        let mut buf = InputBuffer::new(&c, 2, 16); // capacity 2 rows, 1 cycle each
        let a0 = buf.load_next_row(); // t=1
        let a1 = buf.load_next_row(); // t=2
        assert_eq!((a0, a1), (1.0, 2.0));
        // Row 2 cannot start loading until row 0 is released at t=10.
        buf.release_row(0, 10.0);
        let a2 = buf.load_next_row();
        assert_eq!(a2, 11.0);
    }

    #[test]
    fn no_backpressure_with_huge_capacity() {
        let c = clocks(100.0, 100.0, 16);
        let mut buf = InputBuffer::new(&c, 1000, 16);
        for r in 0..100 {
            assert_eq!(buf.load_next_row(), (r + 1) as f64);
        }
    }

    #[test]
    fn peak_occupancy_counts_unreleased_rows() {
        let c = clocks(100.0, 100.0, 16);
        let mut buf = InputBuffer::new(&c, 8, 16);
        for _ in 0..4 {
            buf.load_next_row(); // avail at 1,2,3,4
        }
        // Releases long after all four loaded → peak 4.
        for r in 0..4 {
            buf.release_row(r, 100.0 + r as f64);
        }
        assert_eq!(buf.peak_occupancy(), 4);
    }

    #[test]
    fn loader_aligns_to_inbuff_edges() {
        // ratio = 3 compute cycles per inbuff cycle; a gate at t=4 must
        // round the load start up to the edge at t=6.
        let c = clocks(100.0, 300.0, 16);
        let mut buf = InputBuffer::new(&c, 1, 16);
        let a0 = buf.load_next_row(); // edge 1 → t=3
        assert_eq!(a0, 3.0);
        buf.release_row(0, 4.0);
        let a1 = buf.load_next_row(); // gate 4 → edge 2 (t=6) → done t=9
        assert_eq!(a1, 9.0);
    }
}
