//! The whole-MLP accelerator: layer sequencing over the pipelined
//! matmul engine, bias add, and the sigmoid LUT — the "FPGA" device of
//! Table I.
//!
//! A [`QuantizedMlp`] is an [`crate::nn::Mlp`] whose weight matrices
//! have been SPx-quantized and whose per-layer input scales (`d_scale`,
//! the Q1.15 range) were calibrated on sample data. [`Accelerator`]
//! executes it sample-by-sample, returning both the bit-accurate outputs
//! and the cycle/event trace that the Table-I bench converts to
//! time-per-sample and watts.

use super::pipeline::{run_matvec, LayerRun, PipelineConfig};
use super::power::EnergyModel;
use super::pu::quantize_data_into;
use super::stats::CycleStats;
use crate::nn::activations::{sigmoid_lut, Activation};
use crate::nn::kernels::{simd, spx_matmul_batch, transpose_to_columns};
use crate::nn::mlp::{argmax, Mlp};
use crate::nn::tensor::Matrix;
use crate::quant::spx::{SpxConfig, SpxTensor};
use crate::quant::Calibration;

/// One quantized layer.
#[derive(Debug, Clone)]
pub struct QuantizedLayer {
    pub w: SpxTensor,
    pub b: Vec<f32>,
    pub activation: Activation,
    /// Q1.15 input range for this layer's data operand.
    pub d_scale: f32,
}

impl QuantizedLayer {
    /// One quantized layer of the batched path: quantize `src` to
    /// Q1.15, run the weight-stationary kernel into `dst` (resized in
    /// place — every element is overwritten), then bias + activation in
    /// the same element order as the per-sample path. Every stage is
    /// SIMD-dispatched ([`crate::nn::kernels::simd`]) and bit-identical
    /// to the scalar per-sample loop (pinned by
    /// `forward_batch_matches_infer_one_bitwise`). This is the single
    /// per-layer code path [`Accelerator::forward_batch`] and the
    /// stage-pipelined backend
    /// ([`crate::serve::pipeline_backend::PipelineFpgaBackend`]) share;
    /// `d_fixed`/`d_t` are caller-owned fixed-point staging buffers,
    /// reused across calls.
    pub fn forward_batch_into(
        &self,
        src: &Matrix,
        dst: &mut Matrix,
        d_fixed: &mut Vec<i32>,
        d_t: &mut Vec<i32>,
    ) {
        let batch = src.rows;
        let (m, n) = (self.w.shape[0], self.w.shape[1]);
        debug_assert_eq!(src.cols, n);
        quantize_data_into(&src.data, self.d_scale, d_fixed);
        transpose_to_columns(d_fixed, batch, n, d_t);
        dst.rows = batch;
        dst.cols = m;
        dst.data.resize(batch * m, 0.0);
        // Stats sink None: Accelerator::infer_batch reports the cached
        // simulator trace instead (see Accelerator::per_sample_stats).
        spx_matmul_batch(&self.w, d_t, batch, self.d_scale, &mut dst.data, None);
        simd::active_path().bias_activation(&mut dst.data, &self.b, self.activation);
    }
}

/// An MLP with SPx-quantized weights, ready for the accelerator.
#[derive(Debug, Clone)]
pub struct QuantizedMlp {
    pub layers: Vec<QuantizedLayer>,
}

impl QuantizedMlp {
    /// Quantize a trained MLP. `calib_inputs` (if given) calibrates each
    /// layer's `d_scale` as the max-abs activation over the batch;
    /// otherwise scales default to 1.0 (correct for sigmoid networks on
    /// `[0,1]` inputs — the paper's MNIST setting).
    pub fn from_mlp(
        mlp: &Mlp,
        spx: &SpxConfig,
        calibration: Calibration,
        calib_inputs: Option<&Matrix>,
    ) -> Self {
        // Per-layer input ranges from a calibration pass.
        let mut d_scales = vec![1.0f32; mlp.layers.len()];
        if let Some(x) = calib_inputs {
            let trace = mlp.forward_trace(x);
            for (i, scale) in d_scales.iter_mut().enumerate() {
                let max = trace[i].data.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                if max > 0.0 {
                    *scale = max;
                }
            }
        }
        let layers = mlp
            .layers
            .iter()
            .zip(d_scales)
            .map(|(l, d_scale)| QuantizedLayer {
                w: SpxTensor::encode(
                    spx,
                    &l.w.data,
                    &[l.w.rows, l.w.cols],
                    calibration,
                ),
                b: l.b.clone(),
                activation: l.activation,
                d_scale,
            })
            .collect();
        QuantizedMlp { layers }
    }

    /// Dequantize back to a plain [`Mlp`] — the "fake-quantized" model
    /// used by the XLA/CPU backends so every backend computes with the
    /// same effective weights.
    pub fn to_dequantized_mlp(&self, reference: &Mlp) -> Mlp {
        let mut out = reference.clone();
        for (layer, q) in out.layers.iter_mut().zip(&self.layers) {
            layer.w.data = q.w.decode();
            layer.b = q.b.clone();
        }
        out
    }

    /// Total weight-storage bits under this quantization (signs + codes),
    /// for the compression ratio report.
    pub fn weight_bits(&self) -> u64 {
        self.layers
            .iter()
            .map(|l| l.w.numel() as u64 * l.w.config.total_bits() as u64)
            .sum()
    }
}

/// Accelerator configuration: microarchitecture + energy model.
#[derive(Debug, Clone, Copy)]
pub struct AccelConfig {
    pub pipeline: PipelineConfig,
    pub energy: EnergyModel,
}

impl AccelConfig {
    pub fn default_fpga() -> Self {
        AccelConfig {
            pipeline: PipelineConfig::default_fpga(),
            energy: EnergyModel::default_fpga(),
        }
    }
}

/// The simulated board: a quantized model + its microarchitecture.
pub struct Accelerator {
    /// Invariant: treat as read-only after construction — `decoded`
    /// and `sample_stats` below are derived from it at/after
    /// `Accelerator::new`, and mutating the model in place would
    /// silently desynchronize them. Re-quantizing means building a new
    /// `Accelerator`.
    pub model: QuantizedMlp,
    pub config: AccelConfig,
    /// Per-layer dequantized weight matrices, decoded once at
    /// construction — [`Accelerator::forward_decoded`] used to re-run
    /// `decode()` on every call, which dominated accuracy sweeps.
    decoded: Vec<Matrix>,
    /// One sample's full simulator trace, computed lazily. Every
    /// counter is data-independent (see [`CycleStats::scaled`]), so the
    /// batched path reports `stats × B` exactly.
    sample_stats: once_cell::sync::OnceCell<CycleStats>,
}

impl Accelerator {
    pub fn new(model: QuantizedMlp, config: AccelConfig) -> Self {
        let decoded = model
            .layers
            .iter()
            .map(|l| Matrix::from_vec(l.w.shape[0], l.w.shape[1], l.w.decode()))
            .collect();
        Accelerator { model, config, decoded, sample_stats: once_cell::sync::OnceCell::new() }
    }

    /// Run one sample through every layer; returns the output vector and
    /// the merged cycle/event stats.
    pub fn infer_one(&self, x: &[f32]) -> (Vec<f32>, CycleStats) {
        let mut stats = CycleStats::default();
        let lut = sigmoid_lut();
        let mut a = x.to_vec();
        for layer in &self.model.layers {
            let LayerRun { mut outputs, stats: layer_stats } =
                run_matvec(&layer.w, &a, layer.d_scale, &self.config.pipeline);
            stats.merge(&layer_stats);
            // Bias add + activation in the output stage.
            for (o, &b) in outputs.iter_mut().zip(&layer.b) {
                *o += b;
                stats.adds += 1;
                *o = match layer.activation {
                    Activation::Sigmoid => {
                        stats.lut_lookups += 1;
                        lut.eval(*o)
                    }
                    Activation::Relu => o.max(0.0),
                    Activation::Identity => *o,
                };
            }
            a = outputs;
        }
        (a, stats)
    }

    /// Classify one sample (Eq 4.3).
    pub fn classify_one(&self, x: &[f32]) -> (usize, CycleStats) {
        let (out, stats) = self.infer_one(x);
        (argmax(&out), stats)
    }

    /// Wall-clock seconds one inference takes at the configured compute
    /// clock.
    pub fn seconds_per_inference(&self, stats: &CycleStats) -> f64 {
        self.config.pipeline.clocks.cycles_to_seconds(stats.compute_cycles)
    }

    /// Average power over one inference, watts.
    pub fn power_w(&self, stats: &CycleStats) -> f64 {
        let t = self.seconds_per_inference(stats);
        self.config.energy.average_power_w(stats, t)
    }

    /// Fast functional model: forward with the construction-time
    /// dequantized weights + the sigmoid LUT, skipping the cycle
    /// simulation. Used by accuracy sweeps where only the numbers
    /// matter. Matches [`Accelerator::infer_one`] up to
    /// data-quantization error (pinned by a test).
    pub fn forward_decoded(&self, x: &[f32]) -> Vec<f32> {
        let lut = sigmoid_lut();
        let mut a = x.to_vec();
        for (layer, w) in self.model.layers.iter().zip(&self.decoded) {
            let mut out = vec![0.0f32; w.rows];
            for (r, o) in out.iter_mut().enumerate() {
                let mut acc = 0.0f32;
                for (&wv, &aj) in w.row(r).iter().zip(&a) {
                    acc += wv * aj;
                }
                *o = acc + layer.b[r];
                *o = match layer.activation {
                    Activation::Sigmoid => lut.eval(*o),
                    Activation::Relu => o.max(0.0),
                    Activation::Identity => *o,
                };
            }
            a = out;
        }
        a
    }

    /// The construction-time dequantized weight matrix of layer `i`
    /// (what [`Accelerator::forward_decoded`] multiplies by).
    pub fn decoded_weights(&self, i: usize) -> &Matrix {
        &self.decoded[i]
    }

    /// Batched forward through the weight-stationary SPx shift-add
    /// kernel ([`crate::nn::kernels::spx_batch`]): `x` is
    /// `B × input_dim`, the result `B × output_dim`. One pass over each
    /// layer's packed codes serves the whole batch; per sample the
    /// integer arithmetic is bit-identical to [`Accelerator::infer_one`]
    /// (pinned by a test).
    pub fn forward_batch(&self, x: &Matrix) -> Matrix {
        assert_eq!(
            x.cols,
            self.model.layers[0].w.shape[1],
            "input dim {} vs {}",
            x.cols,
            self.model.layers[0].w.shape[1]
        );
        // Layer outputs ping-pong between two locally owned buffers
        // (layer 0 reads `x` directly — no input clone), and the
        // fixed-point staging vectors are reused across layers.
        let mut ping = Matrix::zeros(0, 0);
        let mut pong = Matrix::zeros(0, 0);
        let mut d_fixed: Vec<i32> = Vec::new();
        let mut d_t: Vec<i32> = Vec::new();
        for (li, layer) in self.model.layers.iter().enumerate() {
            if li == 0 {
                layer.forward_batch_into(x, &mut ping, &mut d_fixed, &mut d_t);
            } else if li % 2 == 1 {
                layer.forward_batch_into(&ping, &mut pong, &mut d_fixed, &mut d_t);
            } else {
                layer.forward_batch_into(&pong, &mut ping, &mut d_fixed, &mut d_t);
            }
        }
        // Layer i writes ping when i is even (cf. Mlp::forward_with).
        if self.model.layers.len() % 2 == 1 {
            ping
        } else {
            pong
        }
    }

    /// Run a whole batch: outputs from [`Accelerator::forward_batch`],
    /// simulator stats as `B ×` the (data-independent) single-sample
    /// trace — exactly what `B` sequential [`Accelerator::infer_one`]
    /// calls would merge, at a fraction of the host cost.
    pub fn infer_batch(&self, x: &Matrix) -> (Matrix, CycleStats) {
        let outputs = self.forward_batch(x);
        let stats = self.per_sample_stats().scaled(x.rows as u64);
        (outputs, stats)
    }

    /// Simulator stats for a `batch`-sample run: `batch ×` the cached
    /// (data-independent) per-sample trace — what
    /// [`Accelerator::infer_batch`] reports, exposed so backends that
    /// compute the outputs elsewhere (the stage-pipelined backend) can
    /// report identical accounting.
    pub fn batch_stats(&self, batch: usize) -> CycleStats {
        self.per_sample_stats().scaled(batch as u64)
    }

    /// Lazily computed single-sample simulator trace (the input values
    /// are irrelevant: every counter is shape/weight-dependent only).
    fn per_sample_stats(&self) -> &CycleStats {
        self.sample_stats.get_or_init(|| {
            let zeros = vec![0.0f32; self.model.layers[0].w.shape[1]];
            self.infer_one(&zeros).1
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::mlp::MlpConfig;
    use crate::util::check::assert_allclose;
    use crate::util::rng::Pcg32;

    fn small_mlp(rng: &mut Pcg32) -> Mlp {
        Mlp::new(
            MlpConfig {
                sizes: vec![12, 8, 4],
                activations: vec![Activation::Sigmoid, Activation::Sigmoid],
            },
            rng,
        )
    }

    #[test]
    fn accelerator_matches_decoded_forward() {
        let mut rng = Pcg32::new(10);
        let mlp = small_mlp(&mut rng);
        let q = QuantizedMlp::from_mlp(&mlp, &SpxConfig::sp2(6), Calibration::MaxAbs, None);
        let acc = Accelerator::new(q, AccelConfig::default_fpga());
        for _ in 0..8 {
            let x: Vec<f32> = (0..12).map(|_| rng.uniform() as f32).collect();
            let (hw, _) = acc.infer_one(&x);
            let sw = acc.forward_decoded(&x);
            // Fixed-point data path adds ≤ ~n·2^-15 per pre-activation.
            assert_allclose(&hw, &sw, 5e-3, 1e-2);
        }
    }

    #[test]
    fn quantized_tracks_fp32_at_high_bits() {
        let mut rng = Pcg32::new(11);
        let mlp = small_mlp(&mut rng);
        let q = QuantizedMlp::from_mlp(&mlp, &SpxConfig::spx(8, 2), Calibration::MaxAbs, None);
        let acc = Accelerator::new(q, AccelConfig::default_fpga());
        for _ in 0..8 {
            let x: Vec<f32> = (0..12).map(|_| rng.uniform() as f32).collect();
            let (hw, _) = acc.infer_one(&x);
            let fp = mlp.forward_one(&x);
            assert_allclose(&hw, &fp, 0.06, 0.1);
        }
    }

    #[test]
    fn stats_accumulate_across_layers() {
        let mut rng = Pcg32::new(12);
        let mlp = small_mlp(&mut rng);
        let q = QuantizedMlp::from_mlp(&mlp, &SpxConfig::sp2(5), Calibration::MaxAbs, None);
        let acc = Accelerator::new(q, AccelConfig::default_fpga());
        let x = vec![0.5f32; 12];
        let (_, stats) = acc.infer_one(&x);
        // MACs = 12·8 + 8·4 = 128.
        assert_eq!(stats.macs, 128);
        // One sigmoid LUT lookup per neuron = 8 + 4.
        assert_eq!(stats.lut_lookups, 12);
        assert!(stats.compute_cycles > 0);
    }

    #[test]
    fn forward_batch_matches_infer_one_bitwise() {
        // The batched weight-stationary kernel is exact integer
        // arithmetic — outputs must equal the per-sample path bit for
        // bit, not just approximately.
        let mut rng = Pcg32::new(20);
        let mlp = small_mlp(&mut rng);
        let q = QuantizedMlp::from_mlp(&mlp, &SpxConfig::sp2(5), Calibration::MaxAbs, None);
        let acc = Accelerator::new(q, AccelConfig::default_fpga());
        for &batch in &[1usize, 2, 7] {
            let x = Matrix::random_uniform(batch, 12, 1.0, &mut rng);
            let batched = acc.forward_batch(&x);
            assert_eq!((batched.rows, batched.cols), (batch, 4));
            for b in 0..batch {
                let (single, _) = acc.infer_one(x.row(b));
                for (got, want) in batched.row(b).iter().zip(&single) {
                    assert_eq!(got.to_bits(), want.to_bits(), "sample {b}");
                }
            }
        }
    }

    #[test]
    fn infer_batch_stats_equal_merged_per_sample_stats() {
        let mut rng = Pcg32::new(21);
        let mlp = small_mlp(&mut rng);
        let q = QuantizedMlp::from_mlp(&mlp, &SpxConfig::spx(7, 3), Calibration::MaxAbs, None);
        let acc = Accelerator::new(q, AccelConfig::default_fpga());
        let x = Matrix::random_uniform(5, 12, 1.0, &mut rng);
        let (_, batch_stats) = acc.infer_batch(&x);
        let mut merged = CycleStats::default();
        for b in 0..5 {
            let (_, s) = acc.infer_one(x.row(b));
            merged.merge(&s);
        }
        assert_eq!(batch_stats, merged);
    }

    #[test]
    fn decoded_weights_cached_at_construction() {
        let mut rng = Pcg32::new(22);
        let mlp = small_mlp(&mut rng);
        let q = QuantizedMlp::from_mlp(&mlp, &SpxConfig::sp2(5), Calibration::MaxAbs, None);
        let acc = Accelerator::new(q, AccelConfig::default_fpga());
        for (i, layer) in acc.model.layers.iter().enumerate() {
            assert_eq!(acc.decoded_weights(i).data, layer.w.decode());
            assert_eq!(acc.decoded_weights(i).rows, layer.w.shape[0]);
        }
    }

    #[test]
    fn dequantized_mlp_has_decoded_weights() {
        let mut rng = Pcg32::new(13);
        let mlp = small_mlp(&mut rng);
        let q = QuantizedMlp::from_mlp(&mlp, &SpxConfig::sp2(4), Calibration::MaxAbs, None);
        let deq = q.to_dequantized_mlp(&mlp);
        assert_eq!(deq.layers[0].w.data, q.layers[0].w.decode());
        // Low-bit decode differs from the original weights.
        assert_ne!(deq.layers[0].w.data, mlp.layers[0].w.data);
    }

    #[test]
    fn calibration_sets_layer_scales() {
        let mut rng = Pcg32::new(14);
        let mlp = small_mlp(&mut rng);
        let x = Matrix::random_uniform(16, 12, 3.0, &mut rng);
        let q =
            QuantizedMlp::from_mlp(&mlp, &SpxConfig::sp2(5), Calibration::MaxAbs, Some(&x));
        // First layer sees the raw inputs (range 3), later layers sigmoid
        // outputs (range ≤ 1).
        assert!(q.layers[0].d_scale > 1.5);
        assert!(q.layers[1].d_scale <= 1.0 + 1e-6);
    }

    #[test]
    fn weight_bits_compression() {
        let mut rng = Pcg32::new(15);
        let mlp = small_mlp(&mut rng);
        let q = QuantizedMlp::from_mlp(&mlp, &SpxConfig::sp2(5), Calibration::MaxAbs, None);
        let params = (12 * 8 + 8 * 4) as u64;
        assert_eq!(q.weight_bits(), params * 5);
        // vs 32-bit floats: >6× compression.
        assert!(params * 32 / q.weight_bits() >= 6);
    }

    #[test]
    fn time_and_power_are_positive_and_sane() {
        let mut rng = Pcg32::new(16);
        let mlp = Mlp::new(MlpConfig::paper_mnist(), &mut rng);
        let q = QuantizedMlp::from_mlp(&mlp, &SpxConfig::sp2(5), Calibration::MaxAbs, None);
        let acc = Accelerator::new(q, AccelConfig::default_fpga());
        let x = vec![0.5f32; 784];
        let (_, stats) = acc.infer_one(&x);
        let t = acc.seconds_per_inference(&stats);
        let p = acc.power_w(&stats);
        // The paper's FPGA row is 1.6 µs @ 10 W; our model should land
        // within two orders of magnitude on time and ~3x on power.
        assert!(t > 1e-7 && t < 1e-3, "time/sample {t}");
        assert!(p > 1.0 && p < 40.0, "power {p} W");
    }
}
