//! Activity-based energy/power model — the substitution for the paper's
//! wall-socket power measurement (DESIGN.md §5).
//!
//! Per-primitive energies are 45 nm-class CMOS estimates in the style of
//! Horowitz (ISSCC'14, "Computing's energy problem") scaled to FPGA
//! fabric (a LUT-fabric op costs ~5-10× an ASIC op; the defaults below
//! bake that in). The point is not the absolute joules but the *ratios*
//! the paper's argument rests on: a shift is ~20× cheaper than a
//! multiply, and keeping data in the input buffer (SRAM) is ~100×
//! cheaper than re-reading RAM.

use super::stats::CycleStats;

/// Energy per primitive event, in picojoules, plus static draw.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnergyModel {
    pub shift_pj: f64,
    pub add_pj: f64,
    pub mult_pj: f64,
    /// Per-word input-buffer (BRAM) read or write.
    pub sram_word_pj: f64,
    /// Per-word external RAM read.
    pub dram_word_pj: f64,
    /// Sigmoid LUT lookup (one BRAM read + interpolation adds).
    pub lut_pj: f64,
    /// Static / leakage + clock-tree power of the whole board, watts.
    pub static_w: f64,
}

impl EnergyModel {
    /// Defaults for an APEX-class FPGA board (fabric-scaled Horowitz
    /// numbers; static draw dominated by the board, not the die).
    pub fn default_fpga() -> Self {
        EnergyModel {
            shift_pj: 1.0,
            add_pj: 4.5,
            mult_pj: 95.0, // 16-bit multiply in fabric
            sram_word_pj: 12.0,
            dram_word_pj: 1280.0,
            lut_pj: 20.0,
            static_w: 2.5,
        }
    }

    /// Dynamic energy of an event trace, joules.
    pub fn dynamic_energy_j(&self, stats: &CycleStats) -> f64 {
        let pj = stats.shifts as f64 * self.shift_pj
            + stats.adds as f64 * self.add_pj
            + stats.mults as f64 * self.mult_pj
            + (stats.buffer_reads + stats.buffer_writes) as f64 * self.sram_word_pj
            + stats.ram_reads as f64 * self.dram_word_pj
            + stats.lut_lookups as f64 * self.lut_pj;
        pj * 1e-12
    }

    /// Total energy over `elapsed_s` seconds (dynamic + static).
    pub fn total_energy_j(&self, stats: &CycleStats, elapsed_s: f64) -> f64 {
        self.dynamic_energy_j(stats) + self.static_w * elapsed_s
    }

    /// Average power over the run, watts.
    pub fn average_power_w(&self, stats: &CycleStats, elapsed_s: f64) -> f64 {
        if elapsed_s <= 0.0 {
            return self.static_w;
        }
        self.total_energy_j(stats, elapsed_s) / elapsed_s
    }
}

/// Platform power constants for the CPU/GPU rows of Table I. The paper
/// *measured* these at the wall (47.2 W / 115.2 W); lacking a meter we
/// import them as documented constants — see DESIGN.md §5.
#[derive(Debug, Clone, Copy)]
pub struct PlatformPower {
    pub cpu_w: f64,
    pub gpu_w: f64,
}

impl PlatformPower {
    pub fn paper_measured() -> Self {
        PlatformPower { cpu_w: 47.2, gpu_w: 115.2 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one_of_each() -> CycleStats {
        CycleStats {
            shifts: 1,
            adds: 1,
            mults: 1,
            buffer_reads: 1,
            buffer_writes: 0,
            ram_reads: 1,
            lut_lookups: 1,
            ..Default::default()
        }
    }

    #[test]
    fn dynamic_energy_sums_events() {
        let m = EnergyModel::default_fpga();
        let e = m.dynamic_energy_j(&one_of_each());
        let expect =
            (m.shift_pj + m.add_pj + m.mult_pj + m.sram_word_pj + m.dram_word_pj + m.lut_pj)
                * 1e-12;
        assert!((e - expect).abs() < 1e-18);
    }

    #[test]
    fn shift_much_cheaper_than_multiply() {
        let m = EnergyModel::default_fpga();
        assert!(m.mult_pj > 20.0 * m.shift_pj);
    }

    #[test]
    fn sram_much_cheaper_than_dram() {
        let m = EnergyModel::default_fpga();
        assert!(m.dram_word_pj > 50.0 * m.sram_word_pj);
    }

    #[test]
    fn average_power_includes_static() {
        let m = EnergyModel::default_fpga();
        let stats = CycleStats::default();
        // No events → power == static.
        assert!((m.average_power_w(&stats, 1.0) - m.static_w).abs() < 1e-12);
    }

    #[test]
    fn power_scales_with_activity_density() {
        let m = EnergyModel::default_fpga();
        let mut stats = CycleStats::default();
        stats.shifts = 1_000_000_000;
        stats.adds = 1_000_000_000;
        let fast = m.average_power_w(&stats, 0.01);
        let slow = m.average_power_w(&stats, 1.0);
        assert!(fast > slow, "same work in less time must draw more power");
    }

    #[test]
    fn zero_elapsed_defends() {
        let m = EnergyModel::default_fpga();
        assert_eq!(m.average_power_w(&CycleStats::default(), 0.0), m.static_w);
    }
}
