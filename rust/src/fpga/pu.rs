//! Processing Unit: the shift-add MAC datapath of §3.1/§3.2.
//!
//! A PU consumes one *reorganized row* — the concatenation `wᵢ ‖ d` of a
//! quantized weight row and the data vector (after Sudrajat [5]) — and
//! produces the dot product `wᵢ · d`, one MAC per compute cycle.
//!
//! Datapath: data elements are Q1.15 fixed point; a weight is a sign and
//! `x` exponent codes; one MAC is `x` barrel shifts of the data word
//! into a guarded 48-bit accumulator (15 guard bits, the width a
//! DSP-free FPGA accumulator would use) plus `x` adds. The only real
//! multipliers in the design sit *after* the accumulator: one per output
//! for the `α/max_sum · d_scale` rescale (§3.1's "quantized float
//! multiplication"), counted as `mults` in the stats.

use crate::quant::spx::SpxTensor;
use super::stats::CycleStats;

/// Fractional bits of the data fixed-point format (Q1.15).
pub const DATA_FRAC_BITS: u32 = 15;
/// Guard bits kept during shifting so truncation error stays below
/// 2^-30 per term (48-bit accumulator datapath). Shared with the packed
/// layout's precomputed shift sums.
pub const GUARD_BITS: u32 = crate::quant::spx::FIXED_GUARD_BITS;

/// Quantize a data value to Q1.15 against `d_scale` (saturating).
#[inline]
pub fn to_fixed(x: f32, d_scale: f32) -> i32 {
    let norm = if d_scale > 0.0 { x / d_scale } else { 0.0 };
    let v = (norm * (1 << DATA_FRAC_BITS) as f32).round();
    v.clamp(-(1 << DATA_FRAC_BITS) as f32, ((1 << DATA_FRAC_BITS) - 1) as f32) as i32
}

/// Back to f32.
#[inline]
pub fn from_fixed(v: i64, d_scale: f32) -> f32 {
    v as f32 / (1u64 << DATA_FRAC_BITS) as f32 * d_scale
}

/// One shift-add MAC: accumulate `w · d` where `w` is (sign, codes) and
/// `d` is a Q1.15 word extended with guard bits. Returns the signed
/// contribution in Q(1.30) (`DATA_FRAC_BITS + GUARD_BITS` fractional
/// bits) and bumps the event counters.
#[inline]
pub fn mac_shift_add(
    d_fixed: i32,
    sign: i8,
    codes: &[u8],
    stats: &mut CycleStats,
) -> i64 {
    let extended = (d_fixed as i64) << GUARD_BITS;
    let mut term_sum = 0i64;
    for &k in codes {
        stats.shifts += 1;
        if k != 0 {
            term_sum += extended >> k;
            stats.adds += 1;
        }
    }
    stats.macs += 1;
    stats.adds += 1; // accumulate into the running dot product
    if sign < 0 {
        -term_sum
    } else {
        term_sum
    }
}

/// Signed shift-add contribution of one packed weight word applied to a
/// guard-extended data value: `sign(word) · Σ_{k≠0} extended >> k` over
/// the `x` 7-bit exponent fields. Shared by the generic per-sample path
/// below and the batched kernel
/// ([`crate::nn::kernels::spx_batch`]) so both compute the identical
/// integer on the slow (k > G) rows.
#[inline(always)]
pub fn packed_term(word: u32, x: usize, extended: i64) -> i64 {
    let mut term = 0i64;
    for t in 0..x {
        let k = (word >> (7 * t)) & 0x7f;
        if k != 0 {
            term += extended >> k;
        }
    }
    if word >> 31 != 0 {
        -term
    } else {
        term
    }
}

/// Compute the full dot product of quantized weight row `row` of `w`
/// against data `d` (f32, scaled by `d_scale`) through the fixed-point
/// shift-add datapath. `w` must be 2-D with rows of length `d.len()`.
///
/// Hot path: arithmetic runs over the element-major [`PackedCodes`]
/// stream (one u32 per weight) with the event counters charged
/// analytically per row — bit-identical to the per-MAC reference
/// [`dot_shift_add_reference`], which a test pins down.
pub fn dot_shift_add(
    w: &SpxTensor,
    row: usize,
    d_fixed: &[i32],
    d_scale: f32,
    stats: &mut CycleStats,
) -> f32 {
    let n = w.shape[1];
    debug_assert_eq!(d_fixed.len(), n);
    let packed = w.packed();
    let words = packed.row_words(row);
    let mut acc = 0i64;
    if packed.row_fast[row] {
        // Every code k in this row satisfies k ≤ G, so
        // `(d << G) >> k == d · 2^{G−k}` exactly and the whole MAC
        // collapses to an integer multiply by the precomputed shift sum
        // — a plain (auto-vectorizable) integer dot product,
        // bit-identical to the shift datapath.
        let values = packed.row_values(row);
        for (&df, &v) in d_fixed.iter().zip(values) {
            acc += df as i64 * v;
        }
        stats.macs += n as u64;
        stats.shifts += (n * packed.x) as u64;
        stats.adds += packed.row_active_terms[row] as u64 + n as u64;
        stats.mults += 1;
        return from_fixed(acc >> GUARD_BITS, d_scale) * w.scale;
    }
    match packed.x {
        1 => {
            for (&df, &word) in d_fixed.iter().zip(words) {
                let extended = (df as i64) << GUARD_BITS;
                let k0 = word & 0x7f;
                let mut term = if k0 != 0 { extended >> k0 } else { 0 };
                if word >> 31 != 0 {
                    term = -term;
                }
                acc += term;
            }
        }
        2 => {
            for (&df, &word) in d_fixed.iter().zip(words) {
                let extended = (df as i64) << GUARD_BITS;
                let (k0, k1) = (word & 0x7f, (word >> 7) & 0x7f);
                let mut term = if k0 != 0 { extended >> k0 } else { 0 };
                if k1 != 0 {
                    term += extended >> k1;
                }
                if word >> 31 != 0 {
                    term = -term;
                }
                acc += term;
            }
        }
        _ => {
            for (&df, &word) in d_fixed.iter().zip(words) {
                let extended = (df as i64) << GUARD_BITS;
                acc += packed_term(word, packed.x, extended);
            }
        }
    }
    // Event accounting, hoisted out of the MAC loop (exact: shifts and
    // MACs are data-independent; adds count the active terms plus one
    // accumulate per MAC; one real multiply at the output stage).
    stats.macs += n as u64;
    stats.shifts += (n * packed.x) as u64;
    stats.adds += packed.row_active_terms[row] as u64 + n as u64;
    stats.mults += 1;
    from_fixed(acc >> GUARD_BITS, d_scale) * w.scale
}

/// Per-MAC reference implementation of [`dot_shift_add`] (kept for the
/// equivalence test and as executable documentation of the datapath).
pub fn dot_shift_add_reference(
    w: &SpxTensor,
    row: usize,
    d_fixed: &[i32],
    d_scale: f32,
    stats: &mut CycleStats,
) -> f32 {
    let n = w.shape[1];
    debug_assert_eq!(d_fixed.len(), n);
    let base = row * n;
    let mut acc = 0i64;
    for (j, &df) in d_fixed.iter().enumerate() {
        let e = base + j;
        let sign = w.signs[e];
        // Gather this element's codes across planes (x of them).
        let mut codes_buf = [0u8; 8];
        let x = w.planes.len();
        for (t, plane) in w.planes.iter().enumerate() {
            codes_buf[t] = plane[e];
        }
        acc += mac_shift_add(df, sign, &codes_buf[..x], stats);
    }
    // Output stage: one real multiply by (scale · d_scale).
    stats.mults += 1;
    from_fixed(acc >> GUARD_BITS, d_scale) * w.scale
}

/// Quantize a whole data vector once (shared across the m rows that all
/// multiply the same `d`, exactly as the reorganized-row preprocessing
/// reuses `d`). Allocating wrapper around [`quantize_data_into`].
pub fn quantize_data(d: &[f32], d_scale: f32) -> Vec<i32> {
    let mut out = Vec::new();
    quantize_data_into(d, d_scale, &mut out);
    out
}

/// [`quantize_data`] into a caller-owned buffer (resized in place) —
/// the allocation-free variant the batched accelerator/backends use on
/// the serving hot path. SIMD-dispatched
/// ([`crate::nn::kernels::simd`]); every path is bit-identical to
/// [`to_fixed`] per element (pinned by property tests).
pub fn quantize_data_into(d: &[f32], d_scale: f32, out: &mut Vec<i32>) {
    // Reshape only — every element is overwritten below, so the warm
    // steady state skips the zero-fill a clear()+resize would redo.
    if out.len() != d.len() {
        out.resize(d.len(), 0);
    }
    crate::nn::kernels::simd::active_path().quantize_into(d, d_scale, out);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::spx::{SpxConfig, SpxTensor};
    use crate::quant::Calibration;
    use crate::util::check::{assert_allclose, property};

    #[test]
    fn fixed_roundtrip_error_bounded() {
        property("Q1.15 roundtrip", 128, |rng| {
            let scale = rng.range(0.1, 10.0) as f32;
            let x = rng.range(-(scale as f64), scale as f64) as f32;
            let back = from_fixed(to_fixed(x, scale) as i64, scale);
            assert!(
                (x - back).abs() <= scale / 32768.0 + 1e-7,
                "x={x} back={back} scale={scale}"
            );
        });
    }

    #[test]
    fn to_fixed_saturates() {
        assert_eq!(to_fixed(2.0, 1.0), (1 << DATA_FRAC_BITS) - 1);
        assert_eq!(to_fixed(-2.0, 1.0), -(1 << DATA_FRAC_BITS));
    }

    #[test]
    fn dot_matches_decoded_f32_reference() {
        // The central PU invariant: the fixed-point shift-add dot product
        // equals the f32 dot product with decoded weights, up to data
        // quantization error (≈ n · d_scale·2^-15 worst case).
        property("shift-add dot == decoded dot", 32, |rng| {
            let n = 8 + rng.index(48);
            let cfg = SpxConfig::spx(2 + rng.index(4) as u32 + 1, 1 + rng.index(2) as u32);
            let wdata: Vec<f32> = (0..2 * n).map(|_| rng.normal() as f32 * 0.4).collect();
            let w = SpxTensor::encode(&cfg, &wdata, &[2, n], Calibration::MaxAbs);
            let d: Vec<f32> = (0..n).map(|_| rng.range(0.0, 1.0) as f32).collect();
            let d_scale = 1.0f32;
            let d_fixed = quantize_data(&d, d_scale);
            let decoded = w.decode();
            let mut stats = CycleStats::default();
            for row in 0..2 {
                let hw = dot_shift_add(&w, row, &d_fixed, d_scale, &mut stats);
                let reference: f32 =
                    decoded[row * n..(row + 1) * n].iter().zip(&d).map(|(a, b)| a * b).sum();
                let tol = n as f32 * d_scale / 32768.0 * w.scale.abs().max(1.0) + 1e-4;
                assert_allclose(&[hw], &[reference], tol, 1e-3);
            }
        });
    }

    #[test]
    fn event_counts_match_formula() {
        let n = 16;
        let x = 3;
        let cfg = SpxConfig::spx(7, x as u32);
        let wdata: Vec<f32> = (0..n).map(|i| (i as f32 - 8.0) / 8.0).collect();
        let w = SpxTensor::encode(&cfg, &wdata, &[1, n], Calibration::MaxAbs);
        let d = vec![0.5f32; n];
        let d_fixed = quantize_data(&d, 1.0);
        let mut stats = CycleStats::default();
        let _ = dot_shift_add(&w, 0, &d_fixed, 1.0, &mut stats);
        assert_eq!(stats.macs, n as u64);
        assert_eq!(stats.shifts, (n * x) as u64);
        assert_eq!(stats.mults, 1);
        // adds: ≤ x per MAC (absent terms don't add) + 1 accumulate each.
        assert!(stats.adds >= n as u64 && stats.adds <= (n * (x + 1)) as u64);
    }

    #[test]
    fn packed_dot_equals_reference() {
        // The hot path must match the per-MAC reference bit-for-bit —
        // outputs AND event counts.
        property("packed == reference dot", 32, |rng| {
            let n = 1 + rng.index(64);
            let x = 1 + rng.index(3) as u32;
            let cfg = SpxConfig::spx(x + 2 + rng.index(3) as u32, x);
            let wdata: Vec<f32> = (0..3 * n).map(|_| rng.normal() as f32).collect();
            let w = SpxTensor::encode(&cfg, &wdata, &[3, n], Calibration::MaxAbs);
            let d: Vec<f32> = (0..n).map(|_| rng.range(-1.0, 1.0) as f32).collect();
            let d_fixed = quantize_data(&d, 1.0);
            for row in 0..3 {
                let mut s1 = CycleStats::default();
                let mut s2 = CycleStats::default();
                let fast = dot_shift_add(&w, row, &d_fixed, 1.0, &mut s1);
                let slow = dot_shift_add_reference(&w, row, &d_fixed, 1.0, &mut s2);
                assert_eq!(fast.to_bits(), slow.to_bits(), "row {row}");
                assert_eq!(s1, s2, "stats diverged at row {row}");
            }
        });
    }

    #[test]
    fn quantize_data_into_matches_quantize_data() {
        property("quantize_data_into == per-element to_fixed", 32, |rng| {
            let n = rng.index(50);
            let scale = rng.range(0.05, 3.0) as f32;
            let lim = 2.0 * scale as f64;
            let d: Vec<f32> = (0..n).map(|_| rng.range(-lim, lim) as f32).collect();
            let want: Vec<i32> = d.iter().map(|&x| to_fixed(x, scale)).collect();
            assert_eq!(quantize_data(&d, scale), want);
            // The into-variant reuses (and fully overwrites) its buffer.
            let mut buf = vec![99i32; 3];
            quantize_data_into(&d, scale, &mut buf);
            assert_eq!(buf, want);
        });
    }

    #[test]
    fn zero_weights_zero_output() {
        let cfg = SpxConfig::sp2(4);
        let w = SpxTensor::encode(&cfg, &[0.0; 8], &[1, 8], Calibration::MaxAbs);
        let d_fixed = quantize_data(&[1.0; 8], 1.0);
        let mut stats = CycleStats::default();
        assert_eq!(dot_shift_add(&w, 0, &d_fixed, 1.0, &mut stats), 0.0);
    }
}
