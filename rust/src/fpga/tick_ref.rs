//! Tick-level reference simulator: executes the §3.1 streaming dataflow
//! one compute-clock edge at a time, with explicit loader / buffer / PU
//! state machines.
//!
//! This is the slow, obviously-correct model that validates the
//! row-analytic scheduler in [`super::pipeline`]: both implement the
//! same microarchitectural contract, and the property test at the bottom
//! pins their cycle counts against each other across random
//! configurations. (The analytic model is what experiments run — it is
//! ~1000× faster — but its correctness claim rests on this
//! cross-check.)
//!
//! Model contract (identical to `run_matvec_streaming`):
//! * loading starts at inbuff-clock edges; one row takes
//!   `ceil(2n/bandwidth)` inbuff cycles; a row is visible at the edge
//!   that completes it; at most `capacity` rows are resident (a row's
//!   slot frees when its PU finishes streaming it);
//! * row `r` is assigned to PU `r mod P`; it may start at integer
//!   compute cycles once (a) resident, (b) its PU is free, and
//!   (c) at least one cycle after row `r-1` started (the stagger);
//! * a row occupies its PU for `ceil(n/lanes)` cycles, then the result
//!   appears `depth` cycles later.

use super::pipeline::PipelineConfig;

/// Cycle outcome of the tick simulation (timing only — numerics are the
/// pipeline module's job).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TickResult {
    pub compute_cycles: u64,
    /// Rows simultaneously resident at the high-water mark.
    pub buffer_peak_rows: u64,
}

/// Run the tick-level model for an `m × n` weight matrix.
///
/// Times are integer compute cycles except loader completions, which
/// land on (possibly fractional) inbuff edges — a PU observes a row at
/// the first compute edge at or after its completion, which is where
/// the analytic model's `ceil` calls come from.
pub fn simulate_streaming(m: usize, n: usize, cfg: &PipelineConfig) -> TickResult {
    assert!(!cfg.weight_resident, "tick reference models the streaming schedule");
    cfg.validate().expect("invalid config");
    let ratio = cfg.clocks.clk_compute_mhz / cfg.clocks.clk_inbuff_mhz;
    let row_words = 2 * n;
    let load_cycles_per_row = (row_words as u64).div_ceil(cfg.clocks.bandwidth_words as u64);
    let busy_cycles = (n as f64 / cfg.lanes as f64).ceil() as u64;

    // Loader state: which row is being transferred, at which inbuff edge
    // it started. The loader may only start row r when fewer than
    // `capacity` rows are resident-or-in-flight ahead of it.
    let mut next_row_to_load = 0usize;
    let mut load_done_edge = vec![f64::INFINITY; m]; // in compute-cycle units
    let mut loader_busy_until_edge = 0u64; // inbuff edges
    // Row lifecycle.
    let mut released = vec![false; m];
    let mut resident = vec![false; m];
    // PU state.
    let mut pu_busy_until = vec![0u64; cfg.num_pus];
    let mut row_started = vec![false; m];
    let mut prev_start_cycle: Option<u64> = None;
    let mut rows_done = 0usize;
    let mut last_finish = 0u64;
    let mut peak = 0u64;

    let mut pending: Vec<(usize, u64)> = Vec::new();
    let mut cycle: u64 = 0;
    let max_cycles = 200_000_000u64; // hard stop against model bugs
    while rows_done < m && cycle < max_cycles {
        // 1. Loader: start new transfers whenever a slot is free. The
        // gate mirrors InputBuffer: row r needs row r-capacity released.
        while next_row_to_load < m {
            let r = next_row_to_load;
            let gate_ok = r < cfg.buffer_capacity_rows
                || released[r - cfg.buffer_capacity_rows];
            if !gate_ok {
                break;
            }
            // The transfer begins at the next inbuff edge ≥ both the
            // loader's free edge and "now" gated by release time; since
            // releases happen at compute cycles, convert now to edges.
            let now_edge = (cycle as f64 / ratio).ceil() as u64;
            let begin_edge = loader_busy_until_edge.max(now_edge);
            let done_edge = begin_edge + load_cycles_per_row;
            load_done_edge[r] = done_edge as f64 * ratio;
            loader_busy_until_edge = done_edge;
            next_row_to_load += 1;
        }

        // 2. Rows become resident at the compute edge ≥ their load
        // completion.
        for r in 0..m {
            if !resident[r] && load_done_edge[r] <= cycle as f64 {
                resident[r] = true;
            }
        }
        let live = (0..m).filter(|&r| resident[r] && !released[r]).count() as u64;
        peak = peak.max(live);

        // 3. PU issue: rows start strictly in order (the stagger chains
        // them), so only the lowest unstarted row can start this cycle.
        if let Some(r) = row_started.iter().position(|&s| !s) {
            let p = r % cfg.num_pus;
            let stagger_ok = match prev_start_cycle {
                None => true,
                Some(prev) => cycle >= prev + 1,
            };
            if resident[r] && stagger_ok && pu_busy_until[p] <= cycle {
                row_started[r] = true;
                prev_start_cycle = Some(cycle);
                pu_busy_until[p] = cycle + busy_cycles;
                let finish = cycle + busy_cycles + cfg.pipeline_depth as u64;
                last_finish = last_finish.max(finish);
                // The row's buffer slot frees when fully streamed.
                pending.push((r, cycle + busy_cycles));
                rows_done += 1;
            }
        }

        // 4. Releases at their completion edges.
        pending.retain(|&(r, t)| {
            if t <= cycle + 1 {
                released[r] = true;
                false
            } else {
                true
            }
        });

        cycle += 1;
    }
    assert!(rows_done == m, "tick model wedged (cycle cap hit)");
    TickResult { compute_cycles: last_finish, buffer_peak_rows: peak }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::clock::ClockConfig;
    use crate::fpga::pipeline::run_matvec;
    use crate::quant::spx::{SpxConfig, SpxTensor};
    use crate::quant::Calibration;
    use crate::util::check::property;

    /// The analytic scheduler allows fractional start times where the
    /// tick model quantizes starts to compute edges, so the two may
    /// differ by at most one cycle per row; the property pins them
    /// within that envelope (and they usually agree much tighter).
    #[test]
    fn analytic_scheduler_matches_tick_reference() {
        property("analytic ≈ tick (≤1 cycle/row)", 16, |rng| {
            let m = 4 + rng.index(24);
            let n = 4 + rng.index(48);
            let cfg = crate::fpga::pipeline::PipelineConfig {
                clocks: ClockConfig {
                    clk_inbuff_mhz: [10.0, 33.0, 66.0, 100.0, 200.0][rng.index(5)],
                    clk_compute_mhz: 100.0,
                    bandwidth_words: [4u32, 16, 64, 256][rng.index(4)],
                },
                num_pus: 1 + rng.index(16),
                buffer_capacity_rows: 1 + rng.index(8),
                pipeline_depth: rng.index(4) as u32,
                lanes: 1 + rng.index(4),
                weight_resident: false,
            };
            // Numeric operands only matter for the analytic path's
            // arithmetic; timing depends on shapes alone.
            let wdata: Vec<f32> = (0..m * n).map(|_| rng.normal() as f32).collect();
            let w = SpxTensor::encode(&SpxConfig::sp2(4), &wdata, &[m, n], Calibration::MaxAbs);
            let d: Vec<f32> = (0..n).map(|_| rng.uniform() as f32).collect();
            let analytic = run_matvec(&w, &d, 1.0, &cfg);
            let tick = simulate_streaming(m, n, &cfg);
            let a = analytic.stats.compute_cycles as i64;
            let t = tick.compute_cycles as i64;
            assert!(
                (a - t).abs() <= m as i64 + 2,
                "analytic {a} vs tick {t} (m={m}, n={n}, cfg={cfg:?})"
            );
            // Peak occupancy agrees within the same envelope.
            let ap = analytic.stats.buffer_peak_rows as i64;
            let tp = tick.buffer_peak_rows as i64;
            assert!((ap - tp).abs() <= 2, "peak {ap} vs {tp}");
        });
    }

    #[test]
    fn tick_model_infinite_bandwidth_formula() {
        // Same closed form the analytic test uses: with instant loading
        // and P >= m, total = first-load + (m-1) + ceil(n/lanes) + depth.
        let cfg = crate::fpga::pipeline::PipelineConfig {
            clocks: ClockConfig {
                clk_inbuff_mhz: 100_000.0,
                clk_compute_mhz: 1.0,
                bandwidth_words: 4096,
            },
            num_pus: 16,
            buffer_capacity_rows: 4096,
            pipeline_depth: 3,
            lanes: 1,
            weight_resident: false,
        };
        let r = simulate_streaming(16, 32, &cfg);
        assert_eq!(r.compute_cycles, 1 + 15 + 32 + 3);
    }
}
