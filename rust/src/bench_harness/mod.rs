//! Hand-rolled benchmark harness (criterion is not in the offline vendor
//! set). Provides warmed-up, repeated timing with mean/σ/p50/p99 stats
//! and an aligned table reporter used by every bench binary.

use crate::util::{mean, percentile, stddev};
use std::time::Instant;

/// Result of timing one benchmark case.
#[derive(Debug, Clone)]
pub struct Timing {
    pub name: String,
    /// Per-iteration wall-clock seconds.
    pub samples: Vec<f64>,
}

impl Timing {
    pub fn mean_s(&self) -> f64 {
        mean(&self.samples)
    }

    pub fn std_s(&self) -> f64 {
        stddev(&self.samples)
    }

    pub fn p50_s(&self) -> f64 {
        percentile(&self.samples, 50.0)
    }

    pub fn p99_s(&self) -> f64 {
        percentile(&self.samples, 99.0)
    }
}

/// Benchmark configuration.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    pub warmup_iters: u32,
    pub measure_iters: u32,
    /// Hard cap on total measurement time; stops early once exceeded.
    pub max_seconds: f64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup_iters: 3, measure_iters: 30, max_seconds: 10.0 }
    }
}

impl BenchConfig {
    /// Quick mode for CI / smoke runs (EDGEMLP_BENCH_QUICK=1).
    pub fn from_env() -> Self {
        if std::env::var("EDGEMLP_BENCH_QUICK").is_ok() {
            BenchConfig { warmup_iters: 1, measure_iters: 5, max_seconds: 2.0 }
        } else {
            BenchConfig::default()
        }
    }
}

/// Time `f` under `config`; `f` is called once per iteration and its
/// return value is black-boxed so the call is not optimized away.
pub fn bench<T>(name: &str, config: BenchConfig, mut f: impl FnMut() -> T) -> Timing {
    for _ in 0..config.warmup_iters {
        black_box(f());
    }
    let mut samples = Vec::with_capacity(config.measure_iters as usize);
    let start = Instant::now();
    for _ in 0..config.measure_iters {
        let t0 = Instant::now();
        black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
        if start.elapsed().as_secs_f64() > config.max_seconds {
            break;
        }
    }
    Timing { name: name.to_string(), samples }
}

/// Identity function the optimizer must treat as opaque.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Format seconds with an adaptive unit.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Escape a string for a JSON string literal (RFC 8259 — note Rust's
/// `escape_default` is NOT JSON: it emits `\'` and `\u{…}`).
fn escape_json(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Minimal flat JSON-object writer for the `BENCH_*.json`
/// perf-trajectory files (EXPERIMENTS.md §Perf) — no serde in the
/// offline vendor set. Keys keep insertion order; values are finite
/// numbers (non-finite renders as `null`) or strings.
#[derive(Debug, Default)]
pub struct BenchJson {
    fields: Vec<(String, String)>,
}

impl BenchJson {
    pub fn new() -> Self {
        BenchJson::default()
    }

    pub fn num(&mut self, key: &str, value: f64) -> &mut Self {
        let rendered = if value.is_finite() { format!("{value}") } else { "null".into() };
        self.fields.push((key.to_string(), rendered));
        self
    }

    pub fn text(&mut self, key: &str, value: &str) -> &mut Self {
        self.fields.push((key.to_string(), format!("\"{}\"", escape_json(value))));
        self
    }

    /// Render the object (pretty-printed, trailing newline).
    pub fn render(&self) -> String {
        let body = self
            .fields
            .iter()
            .map(|(k, v)| format!("  \"{}\": {v}", escape_json(k)))
            .collect::<Vec<_>>()
            .join(",\n");
        format!("{{\n{body}\n}}\n")
    }

    pub fn write(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.render())
    }
}

/// Host fingerprint for `BENCH_*.json` provenance: core count, ISA,
/// SIMD dispatch path, and GEMM pool width. `tools/bench_delta.py`
/// arms its regression gate only when the baseline carries these keys
/// (a fingerprint-less baseline is provisional) and disarms it when
/// they differ — numbers from different hosts are not comparable.
#[derive(Debug, Clone)]
pub struct HostFingerprint {
    pub cores: usize,
    pub arch: &'static str,
    pub dispatch_path: &'static str,
    pub gemm_threads: usize,
}

impl HostFingerprint {
    /// Detect the fingerprint of this process. Respects
    /// `EDGEMLP_FORCE_SCALAR` and `EDGEMLP_GEMM_THREADS`, so it
    /// describes the configuration actually benchmarked, not the raw
    /// silicon.
    pub fn detect() -> Self {
        HostFingerprint {
            cores: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            arch: std::env::consts::ARCH,
            dispatch_path: crate::nn::kernels::active_path().name(),
            gemm_threads: crate::nn::kernels::gemm::configured_threads(),
        }
    }

    /// Stamp the `host_*` keys into a bench JSON object.
    pub fn stamp(&self, json: &mut BenchJson) {
        json.num("host_cores", self.cores as f64);
        json.text("host_arch", self.arch);
        json.text("host_dispatch_path", self.dispatch_path);
        json.num("host_gemm_threads", self.gemm_threads as f64);
    }
}

/// An aligned text table writer for bench reports (also understood by
/// EXPERIMENTS.md — the benches print markdown tables).
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row width");
        self.rows.push(cells.to_vec());
    }

    /// Render as a markdown table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!(" {c:<w$} |"));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let cfg = BenchConfig { warmup_iters: 1, measure_iters: 5, max_seconds: 5.0 };
        let t = bench("noop", cfg, || 42u64);
        assert_eq!(t.samples.len(), 5);
        assert!(t.mean_s() >= 0.0);
    }

    #[test]
    fn bench_respects_time_cap() {
        let cfg = BenchConfig { warmup_iters: 0, measure_iters: 1000, max_seconds: 0.05 };
        let t = bench("sleepy", cfg, || std::thread::sleep(std::time::Duration::from_millis(5)));
        assert!(t.samples.len() < 1000);
    }

    #[test]
    fn fmt_time_units() {
        assert_eq!(fmt_time(2.0), "2.000 s");
        assert_eq!(fmt_time(2.5e-3), "2.500 ms");
        assert_eq!(fmt_time(2.5e-6), "2.500 µs");
        assert_eq!(fmt_time(3e-9), "3.0 ns");
    }

    #[test]
    fn bench_json_renders_valid_flat_object() {
        let mut j = BenchJson::new();
        j.num("gflops", 12.5).num("bad", f64::NAN).text("host", "ci");
        let s = j.render();
        assert_eq!(s, "{\n  \"gflops\": 12.5,\n  \"bad\": null,\n  \"host\": \"ci\"\n}\n");
    }

    #[test]
    fn bench_json_escapes_are_valid_json() {
        let mut j = BenchJson::new();
        j.text("quote\"key", "bob's \"mac\"\nline2\ttab é");
        let s = j.render();
        // JSON-legal escapes only: no \' and no rust-style \u{..}.
        assert_eq!(
            s,
            "{\n  \"quote\\\"key\": \"bob's \\\"mac\\\"\\nline2\\ttab é\"\n}\n"
        );
        assert!(!s.contains("\\'"));
        assert!(!s.contains("\\u{"));
    }

    #[test]
    fn bench_json_write_round_trips() {
        let path = std::env::temp_dir().join("edgemlp_bench_json_test.json");
        let mut j = BenchJson::new();
        j.num("x", 1.0);
        j.write(&path).unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), j.render());
    }

    #[test]
    fn host_fingerprint_stamps_all_keys() {
        let fp = HostFingerprint::detect();
        assert!(fp.cores >= 1);
        assert!(fp.gemm_threads >= 1);
        assert!(!fp.dispatch_path.is_empty());
        let mut j = BenchJson::new();
        fp.stamp(&mut j);
        let s = j.render();
        for key in ["host_cores", "host_arch", "host_dispatch_path", "host_gemm_threads"] {
            assert!(s.contains(key), "fingerprint must emit {key}");
        }
    }

    #[test]
    fn table_renders_markdown() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        let s = t.render();
        assert!(s.starts_with("| a"));
        assert_eq!(s.lines().count(), 3);
    }

    #[test]
    #[should_panic]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a"]);
        t.row(&["1".into(), "2".into()]);
    }
}
