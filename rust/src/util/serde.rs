//! Versioned binary blob format for named f32 tensors ("EMLP" files),
//! plus a minimal JSON value parser for the artifact manifest emitted by
//! `python/compile/aot.py`.
//!
//! Blob layout (all little-endian):
//!
//! ```text
//! magic "EMLP" | u32 version | u32 count |
//!   count × [ u32 name_len | name bytes | u32 ndim | ndim × u64 dim | f32 data ]
//! ```

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

const MAGIC: &[u8; 4] = b"EMLP";
const VERSION: u32 = 1;

/// A named tensor: shape + row-major f32 data.
#[derive(Debug, Clone, PartialEq)]
pub struct NamedTensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl NamedTensor {
    pub fn new(name: impl Into<String>, shape: Vec<usize>, data: Vec<f32>) -> Self {
        let t = NamedTensor { name: name.into(), shape, data };
        assert_eq!(t.shape.iter().product::<usize>(), t.data.len(), "shape/data mismatch");
        t
    }
}

/// Write a set of tensors to `path`.
pub fn save_tensors(path: &Path, tensors: &[NamedTensor]) -> Result<()> {
    let mut buf: Vec<u8> = Vec::new();
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&VERSION.to_le_bytes());
    buf.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
    for t in tensors {
        buf.extend_from_slice(&(t.name.len() as u32).to_le_bytes());
        buf.extend_from_slice(t.name.as_bytes());
        buf.extend_from_slice(&(t.shape.len() as u32).to_le_bytes());
        for &d in &t.shape {
            buf.extend_from_slice(&(d as u64).to_le_bytes());
        }
        for &x in &t.data {
            buf.extend_from_slice(&x.to_le_bytes());
        }
    }
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    f.write_all(&buf)?;
    Ok(())
}

/// Read a tensor set written by [`save_tensors`].
pub fn load_tensors(path: &Path) -> Result<Vec<NamedTensor>> {
    let mut bytes = Vec::new();
    std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?
        .read_to_end(&mut bytes)?;
    let mut cur = Cursor { bytes: &bytes, pos: 0 };
    if cur.take(4)? != MAGIC {
        bail!("bad magic (not an EMLP blob)");
    }
    let version = cur.u32()?;
    if version != VERSION {
        bail!("unsupported blob version {version}");
    }
    let count = cur.u32()? as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let name_len = cur.u32()? as usize;
        let name = String::from_utf8(cur.take(name_len)?.to_vec())
            .context("tensor name not utf8")?;
        let ndim = cur.u32()? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(cur.u64()? as usize);
        }
        let numel: usize = shape.iter().product();
        let data = cur
            .take(numel * 4)?
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        out.push(NamedTensor { name, shape, data });
    }
    if cur.pos != bytes.len() {
        bail!("{} trailing bytes after last tensor", bytes.len() - cur.pos);
    }
    Ok(out)
}

/// Bounds-checked byte reader used by [`load_tensors`].
struct Cursor<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.bytes.len() {
            bail!("truncated blob at offset {} (+{n})", self.pos);
        }
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

// ---------------------------------------------------------------------------
// Minimal JSON (parse-only; enough for aot.py's manifest).
// ---------------------------------------------------------------------------

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = JsonParser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing JSON content at byte {}", p.pos);
        }
        Ok(v)
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            other => bail!("expected object, got {other:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            other => bail!("expected array, got {other:?}"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => bail!("expected number, got {other:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("expected non-negative integer, got {n}");
        }
        Ok(n as usize)
    }

    /// `obj["key"]` with a path-aware error.
    pub fn field(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .with_context(|| format!("missing field '{key}'"))
    }
}

struct JsonParser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> JsonParser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected '{}' at byte {}", b as char, self.pos)
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().context("dangling escape")?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
                            let cp = u32::from_str_radix(hex, 16).context("bad \\u escape")?;
                            self.pos += 4;
                            out.push(char::from_u32(cp).context("bad codepoint")?);
                        }
                        other => bail!("bad escape '\\{}'", other as char),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(s.parse().with_context(|| format!("bad number '{s}'"))?))
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => bail!("expected ',' or ']', got {other:?}"),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => bail!("expected ',' or '}}', got {other:?}"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn tensor_roundtrip() {
        let dir = std::env::temp_dir().join("edgemlp_serde_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.emlp");
        let tensors = vec![
            NamedTensor::new("w1", vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]),
            NamedTensor::new("b1", vec![3], vec![-0.5, 0.0, 0.5]),
            NamedTensor::new("scalar", vec![], vec![7.25]),
        ];
        save_tensors(&path, &tensors).unwrap();
        let back = load_tensors(&path).unwrap();
        assert_eq!(back, tensors);
    }

    #[test]
    fn tensor_roundtrip_property() {
        let dir = std::env::temp_dir().join("edgemlp_serde_prop");
        std::fs::create_dir_all(&dir).unwrap();
        crate::util::check::property("blob roundtrip", 24, |rng| {
            let dir = std::env::temp_dir().join("edgemlp_serde_prop");
            let path = dir.join(format!("t{}.emlp", rng.next_u32()));
            let n = rng.index(4) + 1;
            let tensors: Vec<NamedTensor> = (0..n)
                .map(|i| {
                    let rows = rng.index(5) + 1;
                    let cols = rng.index(5) + 1;
                    let data = (0..rows * cols).map(|_| rng.range(-10.0, 10.0) as f32).collect();
                    NamedTensor::new(format!("t{i}"), vec![rows, cols], data)
                })
                .collect();
            save_tensors(&path, &tensors).unwrap();
            assert_eq!(load_tensors(&path).unwrap(), tensors);
            let _ = std::fs::remove_file(&path);
        });
        // Silence unused warning for the rng-free helper.
        let _ = Pcg32::new(0);
    }

    #[test]
    fn truncated_blob_rejected() {
        let dir = std::env::temp_dir().join("edgemlp_serde_trunc");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.emlp");
        save_tensors(&path, &[NamedTensor::new("w", vec![4], vec![1.0; 4])]).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 3]).unwrap();
        assert!(load_tensors(&path).is_err());
    }

    #[test]
    fn json_basic() {
        let v = Json::parse(r#"{"a": [1, 2.5, -3e2], "b": "hi\n", "c": null, "d": true}"#)
            .unwrap();
        assert_eq!(v.field("b").unwrap().as_str().unwrap(), "hi\n");
        let arr = v.field("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].as_f64().unwrap(), -300.0);
        assert!(matches!(v.field("c").unwrap(), Json::Null));
    }

    #[test]
    fn json_nested() {
        let v = Json::parse(r#"{"m": {"shape": [64, 784], "batch": 64}}"#).unwrap();
        let m = v.field("m").unwrap();
        assert_eq!(m.field("batch").unwrap().as_usize().unwrap(), 64);
        assert_eq!(m.field("shape").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn json_rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":1} x").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn json_unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
    }
}
