//! Minimal property-based testing driver (no `proptest` in the offline
//! vendor set).
//!
//! [`property`] runs a closure over `n` PCG-seeded cases; on failure it
//! reports the case index and the seed that reproduces it, so a failing
//! property can be replayed with `Pcg32::new(seed)` in a unit test.
//!
//! ```no_run
//! # // no_run: rustdoc test binaries miss the xla rpath in this image.
//! use edgemlp::util::check::property;
//! property("abs is non-negative", 256, |rng| {
//!     let x = rng.range(-1e6, 1e6);
//!     assert!(x.abs() >= 0.0);
//! });
//! ```

use super::rng::Pcg32;

/// Base seed; fixed so CI is deterministic. Override with the
/// `EDGEMLP_CHECK_SEED` environment variable to explore other streams.
fn base_seed() -> u64 {
    std::env::var("EDGEMLP_CHECK_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xED6E_517u64)
}

/// Run `f` on `n` independently seeded RNGs. Panics (re-raising the
/// inner panic) with the reproducing seed on the first failing case.
pub fn property<F: Fn(&mut Pcg32) + std::panic::RefUnwindSafe>(name: &str, n: u32, f: F) {
    let base = base_seed();
    for case in 0..n {
        let seed = base ^ ((case as u64) << 32) ^ case as u64;
        let result = std::panic::catch_unwind(|| {
            let mut rng = Pcg32::new(seed);
            f(&mut rng);
        });
        if let Err(payload) = result {
            eprintln!(
                "property '{name}' failed at case {case}/{n} (replay: Pcg32::new({seed:#x}))"
            );
            std::panic::resume_unwind(payload);
        }
    }
}

/// Assert two f32 slices are elementwise close (absolute + relative).
#[track_caller]
pub fn assert_allclose(actual: &[f32], expected: &[f32], atol: f32, rtol: f32) {
    assert_eq!(actual.len(), expected.len(), "length mismatch");
    for (i, (&a, &e)) in actual.iter().zip(expected).enumerate() {
        let tol = atol + rtol * e.abs();
        assert!(
            (a - e).abs() <= tol,
            "index {i}: actual {a} vs expected {e} (|diff| {} > tol {tol})",
            (a - e).abs()
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn property_runs_all_cases() {
        use std::sync::atomic::{AtomicU32, Ordering};
        static COUNT: AtomicU32 = AtomicU32::new(0);
        property("counts", 17, |_| {
            COUNT.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(COUNT.load(Ordering::SeqCst), 17);
    }

    #[test]
    #[should_panic]
    fn property_propagates_failure() {
        property("fails", 8, |rng| {
            assert!(rng.uniform() < 0.5, "eventually exceeds 0.5");
        });
    }

    #[test]
    fn allclose_accepts_equal() {
        assert_allclose(&[1.0, 2.0], &[1.0, 2.0], 0.0, 0.0);
    }

    #[test]
    #[should_panic]
    fn allclose_rejects_far() {
        assert_allclose(&[1.0], &[2.0], 1e-3, 1e-3);
    }
}
