//! Tiny `--flag value` argument parser (no `clap` offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--key`, and a leading
//! subcommand word. Unknown flags are an error so typos fail loudly.

use std::collections::BTreeMap;

/// Parsed command line: an optional subcommand plus string flags.
#[derive(Debug, Default)]
pub struct Args {
    /// First non-flag token, if any (`edgemlp table1 --runs 5` → `table1`).
    pub command: Option<String>,
    flags: BTreeMap<String, String>,
    /// Flag names the caller has consumed — used by [`Args::finish`].
    seen: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of tokens (typically `std::env::args().skip(1)`).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Result<Self, String> {
        let mut args = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                let (key, val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), v.to_string()),
                    None => {
                        // Value is the next token unless it is another flag.
                        let takes_value =
                            it.peek().map(|n| !n.starts_with("--")).unwrap_or(false);
                        if takes_value {
                            (stripped.to_string(), it.next().unwrap())
                        } else {
                            (stripped.to_string(), "true".to_string())
                        }
                    }
                };
                if args.flags.insert(key.clone(), val).is_some() {
                    return Err(format!("duplicate flag --{key}"));
                }
            } else if args.command.is_none() {
                args.command = Some(tok);
            } else {
                return Err(format!("unexpected positional argument '{tok}'"));
            }
        }
        Ok(args)
    }

    /// String flag with default.
    pub fn get(&self, key: &str, default: &str) -> String {
        self.seen.borrow_mut().push(key.to_string());
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Typed flag with default; parse errors become `Err`.
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, String>
    where
        T::Err: std::fmt::Display,
    {
        self.seen.borrow_mut().push(key.to_string());
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| format!("--{key}: {e}")),
        }
    }

    /// Boolean flag (present without value, or `--key true/false`).
    pub fn get_bool(&self, key: &str) -> Result<bool, String> {
        self.seen.borrow_mut().push(key.to_string());
        match self.flags.get(key).map(String::as_str) {
            None => Ok(false),
            Some("true") | Some("1") => Ok(true),
            Some("false") | Some("0") => Ok(false),
            Some(v) => Err(format!("--{key}: expected boolean, got '{v}'")),
        }
    }

    /// Error on any flag that no `get*` call consumed (typo protection).
    pub fn finish(&self) -> Result<(), String> {
        let seen = self.seen.borrow();
        for key in self.flags.keys() {
            if !seen.iter().any(|s| s == key) {
                return Err(format!("unknown flag --{key}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse(&["table1", "--runs", "5", "--batch=64", "--verbose"]);
        assert_eq!(a.command.as_deref(), Some("table1"));
        assert_eq!(a.get_parse("runs", 1u32).unwrap(), 5);
        assert_eq!(a.get_parse("batch", 1u32).unwrap(), 64);
        assert!(a.get_bool("verbose").unwrap());
        a.finish().unwrap();
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]);
        assert_eq!(a.command, None);
        assert_eq!(a.get("model", "mlp"), "mlp");
        assert_eq!(a.get_parse("epochs", 3u32).unwrap(), 3);
    }

    #[test]
    fn duplicate_flag_rejected() {
        assert!(Args::parse(["--x", "1", "--x", "2"].iter().map(|s| s.to_string())).is_err());
    }

    #[test]
    fn unknown_flag_detected() {
        let a = parse(&["--oops", "1"]);
        assert!(a.finish().is_err());
    }

    #[test]
    fn negative_number_value() {
        let a = parse(&["--lr", "-0.5"]);
        assert_eq!(a.get_parse("lr", 0.0f64).unwrap(), -0.5);
    }
}
