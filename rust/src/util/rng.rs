//! Deterministic pseudo-random number generation.
//!
//! PCG32 (O'Neill 2014, `pcg32_srandom_r`/`pcg32_random_r` reference
//! implementation) seeded through SplitMix64. Every stochastic component
//! in the repo (data synthesis, weight init, exploration, property tests)
//! takes an explicit [`Pcg32`] so runs are reproducible from a single
//! seed recorded in EXPERIMENTS.md.

/// SplitMix64 step — used to expand a single u64 seed into PCG state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// PCG32: 64-bit state, 32-bit XSH-RR output.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    const MULT: u64 = 6_364_136_223_846_793_005;

    /// Construct from a seed; the stream constant is derived from the
    /// seed via SplitMix64 so distinct seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let initstate = splitmix64(&mut sm);
        let initseq = splitmix64(&mut sm);
        let mut rng = Pcg32 { state: 0, inc: (initseq << 1) | 1 };
        rng.state = rng.state.wrapping_mul(Self::MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(initstate);
        rng.state = rng.state.wrapping_mul(Self::MULT).wrapping_add(rng.inc);
        rng
    }

    /// Derive an independent child stream (for parallel components).
    pub fn fork(&mut self) -> Self {
        let s = (self.next_u32() as u64) << 32 | self.next_u32() as u64;
        Pcg32::new(s)
    }

    /// Next 32 uniformly random bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(Self::MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64 uniformly random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)` with 32 bits of resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        self.next_u32() as f64 * (1.0 / 4_294_967_296.0)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` via Lemire's unbiased method.
    pub fn below(&mut self, n: u32) -> u32 {
        assert!(n > 0, "below(0)");
        loop {
            let x = self.next_u32();
            let m = (x as u64).wrapping_mul(n as u64);
            let l = m as u32;
            if l >= n || l >= (n.wrapping_neg() % n) {
                return (m >> 32) as u32;
            }
        }
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-12 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample an index in `[0, len)` — convenience for usize containers.
    pub fn index(&mut self, len: usize) -> usize {
        assert!(len > 0 && len <= u32::MAX as usize);
        self.below(len as u32) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Pcg32::new(7);
        for _ in 0..1000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Pcg32::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_mean_and_var_close() {
        let mut r = Pcg32::new(11);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let m = crate::util::mean(&xs);
        let s = crate::util::stddev(&xs);
        assert!(m.abs() < 0.05, "mean {m}");
        assert!((s - 1.0).abs() < 0.05, "std {s}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::new(13);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut a = Pcg32::new(5);
        let mut c = a.fork();
        let same = (0..32).filter(|_| a.next_u32() == c.next_u32()).count();
        assert!(same < 4);
    }
}
