//! # edgemlp
//!
//! Reproduction of *"A Deep Learning Inference Scheme Based on Pipelined
//! Matrix Multiplication Acceleration Design and Non-uniform Quantization"*
//! (Zhang et al., 2021) as a three-layer Rust + JAX + Pallas stack.
//!
//! The paper proposes a low-power MLP inference accelerator for edge
//! devices built from two ingredients:
//!
//! 1. a **pipelined matrix-multiplication dataflow** whose input buffer
//!    decouples data *loading* (clocked by `clk_inbuff`, fed from RAM)
//!    from data *computing* (clocked by `clk_compute`, fed from the
//!    buffer) — see [`fpga`];
//! 2. an **extended sum-of-power-of-two ("SPx") non-uniform
//!    quantization** that turns multiplications into shift-adds — see
//!    [`quant`].
//!
//! Layer map:
//!
//! * **L3 (this crate)** — the coordinator: request [`coordinator`]
//!   (batching, routing, backpressure), the [`serve`] network subsystem
//!   (binary wire protocol, TCP server, hot-swappable model registry,
//!   load generator), the [`obs`] observability layer (request tracing,
//!   Prometheus exposition, energy accounting), the [`runtime`] that
//!   executes AOT-compiled XLA
//!   artifacts via PJRT, and every substrate the paper depends on: a
//!   cycle-accurate [`fpga`] simulator with a power model, a pure-Rust
//!   [`nn`] training stack, the [`data`] pipeline and the [`rl`]
//!   (Acrobot-v1 + Q-learning) harness.
//! * **L2 (python/compile/model.py)** — the JAX MLP forward graph,
//!   lowered once to HLO text under `artifacts/`.
//! * **L1 (python/compile/kernels/)** — the Pallas SPx shift-add matmul
//!   kernel called from L2.
//!
//! Python never runs on the request path: `make artifacts` is the only
//! step that invokes it.

pub mod bench_harness;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod fpga;
pub mod nn;
pub mod obs;
pub mod quant;
pub mod rl;
pub mod runtime;
pub mod serve;
pub mod util;
