//! Minimal episodic-environment interface (the slice of Gym's API the
//! Q-learning experiment needs).

use crate::util::rng::Pcg32;

/// One environment step's outcome. `terminated` is a *true* MDP
/// terminal state (bootstrap stops); `truncated` is an artificial
/// episode cap (bootstrapping must continue through it — conflating the
/// two is the classic time-limit bug that stalls Q-learning).
#[derive(Debug, Clone)]
pub struct Step {
    pub observation: Vec<f32>,
    pub reward: f32,
    pub terminated: bool,
    pub truncated: bool,
}

impl Step {
    /// Episode is over for control-flow purposes.
    pub fn done(&self) -> bool {
        self.terminated || self.truncated
    }
}

/// An episodic RL environment with discrete actions.
pub trait Environment {
    /// Observation vector length.
    fn observation_dim(&self) -> usize;
    /// Number of discrete actions.
    fn num_actions(&self) -> usize;
    /// Reset to a fresh episode; returns the initial observation.
    fn reset(&mut self, rng: &mut Pcg32) -> Vec<f32>;
    /// Apply `action`; advances one step.
    fn step(&mut self, action: usize) -> Step;
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A two-step dummy env for harness tests elsewhere.
    pub struct Dummy {
        t: u32,
    }

    impl Environment for Dummy {
        fn observation_dim(&self) -> usize {
            1
        }
        fn num_actions(&self) -> usize {
            2
        }
        fn reset(&mut self, _rng: &mut Pcg32) -> Vec<f32> {
            self.t = 0;
            vec![0.0]
        }
        fn step(&mut self, _action: usize) -> Step {
            self.t += 1;
            Step {
                observation: vec![self.t as f32],
                reward: -1.0,
                terminated: self.t >= 2,
                truncated: false,
            }
        }
    }

    #[test]
    fn dummy_terminates() {
        let mut env = Dummy { t: 0 };
        let mut rng = Pcg32::new(0);
        let _ = env.reset(&mut rng);
        assert!(!env.step(0).done());
        assert!(env.step(0).done());
    }
}
