//! Reinforcement-learning substrate for the paper's §4.2 experiment:
//! Q-learning with an MLP Q-function on Acrobot-v1.
//!
//! OpenAI Gym is not available offline, so [`acrobot`] is a faithful
//! port of Gym's `AcrobotEnv` ("book" dynamics, RK4, dt = 0.2) — see
//! DESIGN.md §5. [`qlearn`] implements semi-gradient Q-learning with an
//! experience-replay buffer and a periodically synced target network,
//! training through the [`crate::nn`] substrate. Evaluation can swap
//! the greedy policy's Q-network for any quantized backend, which is
//! how E5 measures fp32-vs-SPx control quality.

pub mod acrobot;
pub mod env;
pub mod qlearn;
pub mod replay;

pub use acrobot::Acrobot;
pub use env::Environment;
pub use qlearn::{QLearnConfig, QLearner};
