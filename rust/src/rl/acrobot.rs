//! Acrobot-v1: a two-link pendulum that must swing its tip above the
//! bar. Faithful port of OpenAI Gym's `AcrobotEnv` (the "book" dynamics
//! of Sutton & Barto §11.3 / Gym's default):
//!
//! * state `(θ₁, θ₂, θ̇₁, θ̇₂)`, observation
//!   `[cos θ₁, sin θ₁, cos θ₂, sin θ₂, θ̇₁, θ̇₂]`;
//! * actions `{0, 1, 2}` → torque `{-1, 0, +1}` on the second joint;
//! * RK4 integration with `dt = 0.2`, velocities clipped to
//!   `±4π / ±9π`, angles wrapped to `[-π, π)`;
//! * reward −1 per step; terminates when
//!   `-cos θ₁ − cos(θ₁ + θ₂) > 1` or after 500 steps;
//! * reset draws all four state components uniformly from `[-0.1, 0.1]`.

use super::env::{Environment, Step};
use crate::util::rng::Pcg32;
use std::f64::consts::PI;

const LINK_LENGTH_1: f64 = 1.0;
const LINK_MASS_1: f64 = 1.0;
const LINK_MASS_2: f64 = 1.0;
const LINK_COM_POS_1: f64 = 0.5;
const LINK_COM_POS_2: f64 = 0.5;
const LINK_MOI: f64 = 1.0;
const GRAVITY: f64 = 9.8;
const DT: f64 = 0.2;
const MAX_VEL_1: f64 = 4.0 * PI;
const MAX_VEL_2: f64 = 9.0 * PI;
const TORQUES: [f64; 3] = [-1.0, 0.0, 1.0];
const MAX_STEPS: u32 = 500;

/// The Acrobot-v1 environment.
#[derive(Debug, Clone)]
pub struct Acrobot {
    /// `(θ₁, θ₂, θ̇₁, θ̇₂)`.
    state: [f64; 4],
    steps: u32,
}

impl Default for Acrobot {
    fn default() -> Self {
        Acrobot { state: [0.0; 4], steps: 0 }
    }
}

impl Acrobot {
    pub fn new() -> Self {
        Self::default()
    }

    /// Gym's `_dsdt`: time derivative of the augmented state under
    /// torque `a` ("book" variant).
    fn dsdt(s: &[f64; 4], a: f64) -> [f64; 4] {
        let (m1, m2) = (LINK_MASS_1, LINK_MASS_2);
        let (l1, lc1, lc2) = (LINK_LENGTH_1, LINK_COM_POS_1, LINK_COM_POS_2);
        let (i1, i2, g) = (LINK_MOI, LINK_MOI, GRAVITY);
        let [theta1, theta2, dtheta1, dtheta2] = *s;

        let d1 = m1 * lc1 * lc1
            + m2 * (l1 * l1 + lc2 * lc2 + 2.0 * l1 * lc2 * theta2.cos())
            + i1
            + i2;
        let d2 = m2 * (lc2 * lc2 + l1 * lc2 * theta2.cos()) + i2;
        let phi2 = m2 * lc2 * g * (theta1 + theta2 - PI / 2.0).cos();
        let phi1 = -m2 * l1 * lc2 * dtheta2 * dtheta2 * theta2.sin()
            - 2.0 * m2 * l1 * lc2 * dtheta2 * dtheta1 * theta2.sin()
            + (m1 * lc1 + m2 * l1) * g * (theta1 - PI / 2.0).cos()
            + phi2;
        // "book" dynamics (Gym's default `book_or_nips = "book"`).
        let ddtheta2 = (a + d2 / d1 * phi1
            - m2 * l1 * lc2 * dtheta1 * dtheta1 * theta2.sin()
            - phi2)
            / (m2 * lc2 * lc2 + i2 - d2 * d2 / d1);
        let ddtheta1 = -(d2 * ddtheta2 + phi1) / d1;
        [dtheta1, dtheta2, ddtheta1, ddtheta2]
    }

    /// One RK4 step of length `DT` under constant torque `a`.
    fn rk4(s: &[f64; 4], a: f64) -> [f64; 4] {
        let add = |x: &[f64; 4], k: &[f64; 4], h: f64| {
            [x[0] + h * k[0], x[1] + h * k[1], x[2] + h * k[2], x[3] + h * k[3]]
        };
        let k1 = Self::dsdt(s, a);
        let k2 = Self::dsdt(&add(s, &k1, DT / 2.0), a);
        let k3 = Self::dsdt(&add(s, &k2, DT / 2.0), a);
        let k4 = Self::dsdt(&add(s, &k3, DT), a);
        [
            s[0] + DT / 6.0 * (k1[0] + 2.0 * k2[0] + 2.0 * k3[0] + k4[0]),
            s[1] + DT / 6.0 * (k1[1] + 2.0 * k2[1] + 2.0 * k3[1] + k4[1]),
            s[2] + DT / 6.0 * (k1[2] + 2.0 * k2[2] + 2.0 * k3[2] + k4[2]),
            s[3] + DT / 6.0 * (k1[3] + 2.0 * k2[3] + 2.0 * k3[3] + k4[3]),
        ]
    }

    fn observation(&self) -> Vec<f32> {
        let [t1, t2, dt1, dt2] = self.state;
        vec![
            t1.cos() as f32,
            t1.sin() as f32,
            t2.cos() as f32,
            t2.sin() as f32,
            dt1 as f32,
            dt2 as f32,
        ]
    }

    fn terminal(&self) -> bool {
        let [t1, t2, _, _] = self.state;
        -t1.cos() - (t1 + t2).cos() > 1.0
    }

    /// Direct state access for physics tests.
    pub fn state(&self) -> [f64; 4] {
        self.state
    }

    pub fn set_state(&mut self, s: [f64; 4]) {
        self.state = s;
        self.steps = 0;
    }

    /// Total mechanical energy (kinetic + potential), used by the
    /// integration-accuracy test (conserved under zero torque up to RK4
    /// error).
    pub fn energy(&self) -> f64 {
        let (m1, m2) = (LINK_MASS_1, LINK_MASS_2);
        let (l1, lc1, lc2) = (LINK_LENGTH_1, LINK_COM_POS_1, LINK_COM_POS_2);
        let (i1, i2, g) = (LINK_MOI, LINK_MOI, GRAVITY);
        let [t1, t2, dt1, dt2] = self.state;
        // Heights of the two centers of mass (y up, pivot at origin;
        // θ measured from the downward vertical).
        let y1 = -lc1 * t1.cos();
        let y2 = -l1 * t1.cos() - lc2 * (t1 + t2).cos();
        let potential = m1 * g * y1 + m2 * g * y2;
        // Velocities of the COMs.
        let v1sq = (lc1 * dt1) * (lc1 * dt1);
        let v2x = l1 * dt1 * t1.cos() + lc2 * (dt1 + dt2) * (t1 + t2).cos();
        let v2y = l1 * dt1 * t1.sin() + lc2 * (dt1 + dt2) * (t1 + t2).sin();
        let kinetic = 0.5 * m1 * v1sq
            + 0.5 * m2 * (v2x * v2x + v2y * v2y)
            + 0.5 * i1 * dt1 * dt1
            + 0.5 * i2 * (dt1 + dt2) * (dt1 + dt2);
        kinetic + potential
    }
}

/// Wrap an angle to `[-π, π)`.
fn wrap_pi(x: f64) -> f64 {
    let two_pi = 2.0 * PI;
    let mut v = (x + PI) % two_pi;
    if v < 0.0 {
        v += two_pi;
    }
    v - PI
}

impl Environment for Acrobot {
    fn observation_dim(&self) -> usize {
        6
    }

    fn num_actions(&self) -> usize {
        3
    }

    fn reset(&mut self, rng: &mut Pcg32) -> Vec<f32> {
        for s in &mut self.state {
            *s = rng.range(-0.1, 0.1);
        }
        self.steps = 0;
        self.observation()
    }

    fn step(&mut self, action: usize) -> Step {
        assert!(action < 3, "acrobot action {action}");
        let torque = TORQUES[action];
        let mut next = Self::rk4(&self.state, torque);
        next[0] = wrap_pi(next[0]);
        next[1] = wrap_pi(next[1]);
        next[2] = next[2].clamp(-MAX_VEL_1, MAX_VEL_1);
        next[3] = next[3].clamp(-MAX_VEL_2, MAX_VEL_2);
        self.state = next;
        self.steps += 1;
        let terminated = self.terminal();
        let reward = if terminated { 0.0 } else { -1.0 };
        Step {
            observation: self.observation(),
            reward,
            terminated,
            truncated: !terminated && self.steps >= MAX_STEPS,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::property;

    #[test]
    fn reset_starts_near_rest() {
        let mut env = Acrobot::new();
        let mut rng = Pcg32::new(0);
        let obs = env.reset(&mut rng);
        assert_eq!(obs.len(), 6);
        // Near-downward: cos θ₁ ≈ 1.
        assert!(obs[0] > 0.99);
        assert!(!env.terminal());
    }

    #[test]
    fn observation_components_consistent() {
        property("cos²+sin² == 1", 32, |rng| {
            let mut env = Acrobot::new();
            let _ = env.reset(rng);
            for _ in 0..10 {
                let s = env.step(rng.index(3));
                let o = &s.observation;
                assert!((o[0] * o[0] + o[1] * o[1] - 1.0).abs() < 1e-5);
                assert!((o[2] * o[2] + o[3] * o[3] - 1.0).abs() < 1e-5);
            }
        });
    }

    #[test]
    fn velocities_clipped() {
        let mut env = Acrobot::new();
        let mut rng = Pcg32::new(1);
        let _ = env.reset(&mut rng);
        for _ in 0..500 {
            let s = env.step(2);
            assert!(s.observation[4].abs() <= (MAX_VEL_1 as f32) + 1e-4);
            assert!(s.observation[5].abs() <= (MAX_VEL_2 as f32) + 1e-4);
            if s.done() {
                break;
            }
        }
    }

    #[test]
    fn episode_caps_at_500_steps() {
        let mut env = Acrobot::new();
        let mut rng = Pcg32::new(2);
        let _ = env.reset(&mut rng);
        let mut steps = 0;
        loop {
            let s = env.step(1); // zero torque: hangs forever
            steps += 1;
            if s.done() {
                assert!(s.truncated && !s.terminated);
                break;
            }
            assert!(steps <= 500);
        }
        assert_eq!(steps, 500);
    }

    #[test]
    fn energy_conserved_without_torque() {
        // RK4 at dt=0.2 drifts slightly; over 50 steps the drift should
        // stay under 1% of the energy scale.
        let mut env = Acrobot::new();
        env.set_state([1.0, 0.5, 0.0, 0.0]);
        let e0 = env.energy();
        for _ in 0..50 {
            let _ = env.step(1); // zero torque
        }
        let e1 = env.energy();
        assert!(
            (e1 - e0).abs() < 0.3,
            "energy drift {e0} → {e1}"
        );
    }

    #[test]
    fn torque_injects_energy() {
        let mut env = Acrobot::new();
        env.set_state([0.01, 0.0, 0.0, 0.0]);
        let e0 = env.energy();
        // Bang-bang torque pumps energy into the system.
        for i in 0..40 {
            let a = if (i / 5) % 2 == 0 { 2 } else { 0 };
            let _ = env.step(a);
        }
        assert!(env.energy() > e0 + 0.5, "e0={e0} e1={}", env.energy());
    }

    #[test]
    fn terminal_condition_matches_formula() {
        let mut env = Acrobot::new();
        // Tip straight up: θ₁ = π (link 1 up), θ₂ = 0 → height = 2.
        env.set_state([PI, 0.0, 0.0, 0.0]);
        assert!(env.terminal());
        // Hanging down: height = -2.
        env.set_state([0.0, 0.0, 0.0, 0.0]);
        assert!(!env.terminal());
    }

    #[test]
    fn wrap_pi_range() {
        property("wrap_pi ∈ [-π, π)", 64, |rng| {
            let x = rng.range(-50.0, 50.0);
            let w = wrap_pi(x);
            assert!((-PI..PI).contains(&w), "{x} → {w}");
            // Same angle modulo 2π.
            let turns = (x - w) / (2.0 * PI);
            assert!((turns - turns.round()).abs() < 1e-9);
        });
    }

    #[test]
    fn dynamics_deterministic() {
        let mut a = Acrobot::new();
        let mut b = Acrobot::new();
        a.set_state([0.05, -0.03, 0.01, 0.02]);
        b.set_state([0.05, -0.03, 0.01, 0.02]);
        for i in 0..20 {
            let sa = a.step(i % 3);
            let sb = b.step(i % 3);
            assert_eq!(sa.observation, sb.observation);
        }
    }
}
