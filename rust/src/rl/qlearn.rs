//! Semi-gradient Q-learning with an MLP function approximator (§4.2):
//! ε-greedy exploration, experience replay, and a periodically synced
//! target network (the standard DQN stabilizers — without them sigmoid
//! Q-MLPs of this size diverge on Acrobot).
//!
//! The *policy* at evaluation time is pluggable: any `Fn(&[f32]) ->
//! Vec<f32>` can provide Q-values, so the same evaluation harness runs
//! the fp32 network, the SPx-quantized accelerator, or the XLA artifact
//! — that comparison is experiment E5.

use super::env::Environment;
use super::replay::{ReplayBuffer, Transition};
use crate::nn::mlp::{argmax, Mlp, MlpConfig};
use crate::nn::tensor::Matrix;
use crate::nn::train::{apply_gradients, backward_regression};
use crate::util::rng::Pcg32;

/// Q-learning hyper-parameters.
#[derive(Debug, Clone)]
pub struct QLearnConfig {
    pub episodes: usize,
    pub gamma: f32,
    pub learning_rate: f32,
    pub batch_size: usize,
    pub replay_capacity: usize,
    /// Linear ε decay from `eps_start` to `eps_end` over `eps_decay_steps`.
    pub eps_start: f64,
    pub eps_end: f64,
    pub eps_decay_steps: u64,
    /// Sync the target network every this many gradient steps.
    pub target_sync_every: u64,
    /// Environment steps before learning starts.
    pub warmup_steps: u64,
    pub seed: u64,
}

impl Default for QLearnConfig {
    fn default() -> Self {
        QLearnConfig {
            episodes: 150,
            gamma: 0.99,
            learning_rate: 0.01,
            batch_size: 64,
            replay_capacity: 50_000,
            eps_start: 1.0,
            eps_end: 0.05,
            eps_decay_steps: 20_000,
            target_sync_every: 500,
            warmup_steps: 1_000,
            seed: 7,
        }
    }
}

/// Per-episode training record.
#[derive(Debug, Clone)]
pub struct EpisodeStats {
    pub episode: usize,
    pub return_sum: f32,
    pub steps: u32,
    pub epsilon: f64,
}

/// The learner: online network, target network, replay.
pub struct QLearner {
    pub qnet: Mlp,
    target: Mlp,
    replay: ReplayBuffer,
    config: QLearnConfig,
    env_steps: u64,
    grad_steps: u64,
    rng: Pcg32,
}

impl QLearner {
    pub fn new(env: &dyn Environment, config: QLearnConfig) -> Self {
        let mut rng = Pcg32::new(config.seed);
        let arch = MlpConfig {
            sizes: vec![env.observation_dim(), 64, 64, env.num_actions()],
            activations: MlpConfig::paper_qnet().activations,
        };
        let qnet = Mlp::new(arch, &mut rng);
        let target = qnet.clone();
        let replay = ReplayBuffer::new(config.replay_capacity);
        QLearner { qnet, target, replay, config, env_steps: 0, grad_steps: 0, rng }
    }

    /// Current exploration rate.
    pub fn epsilon(&self) -> f64 {
        let c = &self.config;
        let frac = (self.env_steps as f64 / c.eps_decay_steps as f64).min(1.0);
        c.eps_start + (c.eps_end - c.eps_start) * frac
    }

    /// ε-greedy action from the online network.
    fn act(&mut self, obs: &[f32]) -> usize {
        if self.rng.uniform() < self.epsilon() {
            self.rng.index(self.qnet.output_dim())
        } else {
            argmax(&self.qnet.forward_one(obs))
        }
    }

    /// One replayed gradient step (if warm enough).
    fn learn(&mut self) {
        if self.replay.len() < self.config.batch_size
            || self.env_steps < self.config.warmup_steps
        {
            return;
        }
        let batch = self.config.batch_size;
        let obs_dim = self.qnet.input_dim();
        let n_actions = self.qnet.output_dim();
        // Assemble the batch.
        let samples = self.replay.sample(batch, &mut self.rng);
        let mut states = Matrix::zeros(batch, obs_dim);
        let mut next_states = Matrix::zeros(batch, obs_dim);
        let mut actions = Vec::with_capacity(batch);
        let mut rewards = Vec::with_capacity(batch);
        let mut dones = Vec::with_capacity(batch);
        for (i, t) in samples.iter().enumerate() {
            states.data[i * obs_dim..(i + 1) * obs_dim].copy_from_slice(&t.state);
            next_states.data[i * obs_dim..(i + 1) * obs_dim].copy_from_slice(&t.next_state);
            actions.push(t.action);
            rewards.push(t.reward);
            dones.push(t.done);
        }
        // TD targets from the frozen target network.
        let next_q = self.target.forward(&next_states);
        let acts = self.qnet.forward_trace(&states);
        let current_q = acts.last().unwrap();
        let mut targets = current_q.clone();
        let mut mask = Matrix::zeros(batch, n_actions);
        for i in 0..batch {
            let max_next = next_q.row(i).iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let td = if dones[i] {
                rewards[i]
            } else {
                rewards[i] + self.config.gamma * max_next
            };
            *targets.at_mut(i, actions[i]) = td;
            *mask.at_mut(i, actions[i]) = 1.0;
        }
        let grads = backward_regression(&self.qnet, &acts, &targets, Some(&mask));
        apply_gradients(&mut self.qnet, &grads, self.config.learning_rate);
        self.grad_steps += 1;
        if self.grad_steps % self.config.target_sync_every == 0 {
            self.target = self.qnet.clone();
        }
    }

    /// Train for `config.episodes` episodes on `env`.
    pub fn train(&mut self, env: &mut dyn Environment) -> Vec<EpisodeStats> {
        let mut stats = Vec::with_capacity(self.config.episodes);
        for episode in 0..self.config.episodes {
            let mut obs = env.reset(&mut self.rng);
            let mut return_sum = 0.0f32;
            let mut steps = 0u32;
            loop {
                let action = self.act(&obs);
                let step = env.step(action);
                self.env_steps += 1;
                return_sum += step.reward;
                steps += 1;
                self.replay.push(Transition {
                    state: obs.clone(),
                    action,
                    reward: step.reward,
                    next_state: step.observation.clone(),
                    // Bootstrap through truncation — only true terminals
                    // stop the TD backup (time-limit correctness).
                    done: step.terminated,
                });
                self.learn();
                let done = step.done();
                obs = step.observation;
                if done {
                    break;
                }
            }
            stats.push(EpisodeStats { episode, return_sum, steps, epsilon: self.epsilon() });
        }
        stats
    }
}

/// Evaluate a greedy policy given by `q_fn` for `episodes` episodes;
/// returns per-episode returns. This is the harness E5 uses with
/// different inference backends.
pub fn evaluate_policy(
    env: &mut dyn Environment,
    q_fn: &mut dyn FnMut(&[f32]) -> Vec<f32>,
    episodes: usize,
    seed: u64,
) -> Vec<f32> {
    let mut rng = Pcg32::new(seed);
    let mut returns = Vec::with_capacity(episodes);
    for _ in 0..episodes {
        let mut obs = env.reset(&mut rng);
        let mut total = 0.0f32;
        loop {
            let action = argmax(&q_fn(&obs));
            let step = env.step(action);
            total += step.reward;
            let done = step.done();
            obs = step.observation;
            if done {
                break;
            }
        }
        returns.push(total);
    }
    returns
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rl::acrobot::Acrobot;
    use crate::util::mean;

    /// Trivial env: two states; action 0 ends the episode with reward
    /// +1, action 1 continues with reward 0 (cap 10 steps). Optimal
    /// return = 1 immediately.
    struct Bandit {
        t: u32,
    }

    impl Environment for Bandit {
        fn observation_dim(&self) -> usize {
            2
        }
        fn num_actions(&self) -> usize {
            2
        }
        fn reset(&mut self, _rng: &mut Pcg32) -> Vec<f32> {
            self.t = 0;
            vec![1.0, 0.0]
        }
        fn step(&mut self, action: usize) -> super::super::env::Step {
            self.t += 1;
            let terminated = action == 0 || self.t >= 10;
            super::super::env::Step {
                observation: vec![0.0, 1.0],
                reward: if action == 0 { 1.0 } else { 0.0 },
                terminated,
                truncated: false,
            }
        }
    }

    #[test]
    fn learns_trivial_bandit() {
        let mut env = Bandit { t: 0 };
        let config = QLearnConfig {
            episodes: 200,
            warmup_steps: 50,
            eps_decay_steps: 300,
            target_sync_every: 50,
            learning_rate: 0.05,
            batch_size: 16,
            ..Default::default()
        };
        let mut learner = QLearner::new(&env, config);
        let _ = learner.train(&mut env);
        // Greedy policy should pick action 0 in the start state.
        let q = learner.qnet.forward_one(&[1.0, 0.0]);
        assert!(q[0] > q[1], "q-values {q:?}");
    }

    #[test]
    fn epsilon_decays_linearly() {
        let env = Bandit { t: 0 };
        let mut learner = QLearner::new(&env, QLearnConfig::default());
        assert_eq!(learner.epsilon(), 1.0);
        learner.env_steps = learner.config.eps_decay_steps;
        assert!((learner.epsilon() - learner.config.eps_end).abs() < 1e-9);
        learner.env_steps = learner.config.eps_decay_steps * 10;
        assert!((learner.epsilon() - learner.config.eps_end).abs() < 1e-9);
    }

    #[test]
    fn evaluate_policy_runs_episodes() {
        let mut env = Acrobot::new();
        let mut constant_q = |_obs: &[f32]| vec![0.0, 1.0, 0.0];
        let returns = evaluate_policy(&mut env, &mut constant_q, 3, 0);
        assert_eq!(returns.len(), 3);
        // Zero-torque policy never solves acrobot: returns = -500.
        assert!(mean(&returns.iter().map(|&r| r as f64).collect::<Vec<_>>()) <= -499.0);
    }

    #[test]
    fn qnet_shapes_match_env() {
        let env = Acrobot::new();
        let learner = QLearner::new(&env, QLearnConfig::default());
        assert_eq!(learner.qnet.input_dim(), 6);
        assert_eq!(learner.qnet.output_dim(), 3);
    }
}
