//! Fixed-capacity experience-replay ring buffer with uniform sampling.

use crate::util::rng::Pcg32;

/// One transition `(s, a, r, s', done)`.
#[derive(Debug, Clone)]
pub struct Transition {
    pub state: Vec<f32>,
    pub action: usize,
    pub reward: f32,
    pub next_state: Vec<f32>,
    pub done: bool,
}

/// Ring buffer of transitions.
pub struct ReplayBuffer {
    capacity: usize,
    items: Vec<Transition>,
    head: usize,
}

impl ReplayBuffer {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        ReplayBuffer { capacity, items: Vec::with_capacity(capacity), head: 0 }
    }

    pub fn push(&mut self, t: Transition) {
        if self.items.len() < self.capacity {
            self.items.push(t);
        } else {
            self.items[self.head] = t;
            self.head = (self.head + 1) % self.capacity;
        }
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Sample `n` transitions uniformly with replacement.
    pub fn sample<'a>(&'a self, n: usize, rng: &mut Pcg32) -> Vec<&'a Transition> {
        assert!(!self.is_empty(), "sampling empty replay buffer");
        (0..n).map(|_| &self.items[rng.index(self.items.len())]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(v: f32) -> Transition {
        Transition {
            state: vec![v],
            action: 0,
            reward: v,
            next_state: vec![v + 1.0],
            done: false,
        }
    }

    #[test]
    fn grows_until_capacity_then_overwrites() {
        let mut buf = ReplayBuffer::new(3);
        for i in 0..5 {
            buf.push(t(i as f32));
        }
        assert_eq!(buf.len(), 3);
        // 0 and 1 were overwritten by 3 and 4.
        let rewards: Vec<f32> = buf.items.iter().map(|x| x.reward).collect();
        assert!(rewards.contains(&2.0) && rewards.contains(&3.0) && rewards.contains(&4.0));
    }

    #[test]
    fn sample_returns_requested_count() {
        let mut buf = ReplayBuffer::new(8);
        for i in 0..4 {
            buf.push(t(i as f32));
        }
        let mut rng = Pcg32::new(0);
        assert_eq!(buf.sample(16, &mut rng).len(), 16);
    }

    #[test]
    #[should_panic]
    fn sample_empty_panics() {
        let buf = ReplayBuffer::new(4);
        let mut rng = Pcg32::new(0);
        let _ = buf.sample(1, &mut rng);
    }

    #[test]
    fn sampling_covers_buffer() {
        let mut buf = ReplayBuffer::new(16);
        for i in 0..16 {
            buf.push(t(i as f32));
        }
        let mut rng = Pcg32::new(1);
        let seen: std::collections::BTreeSet<i32> =
            buf.sample(400, &mut rng).iter().map(|t| t.reward as i32).collect();
        assert!(seen.len() >= 14, "only {} distinct transitions sampled", seen.len());
    }
}
