//! Prometheus text-format (version 0.0.4) exposition of the serving
//! metrics: every [`MetricsSnapshot`] field — per-pool counters, the
//! native histogram buckets, shed/expired/degraded state, stage
//! occupancy, energy — as properly typed, labeled families.
//!
//! Family and label names are part of the observable API and pinned by
//! a golden-file test; `tools/check_metrics.py` validates the rendered
//! format (and the required families) in CI against a live `/metrics`
//! scrape. All lines of one family are contiguous, as the exposition
//! format requires.

use super::energy::pool_energy;
use crate::coordinator::metrics::MetricsSnapshot;
use crate::fpga::power::EnergyModel;
use crate::serve::wire::{HealthReport, LoopGauges};

/// Autoscaler state for one scrape. The families it feeds are emitted
/// unconditionally — a server running without an autoscaler exports
/// zero counters and a degenerate replica band (`min == max ==
/// current`), so dashboards and `tools/check_metrics.py` see the same
/// family set either way.
#[derive(Debug, Clone, Copy, Default)]
pub struct AutoscaleExport {
    /// True when an autoscaler thread is running.
    pub enabled: bool,
    /// Configured replica floor (meaningful only when `enabled`).
    pub min_replicas: u64,
    /// Configured replica ceiling (meaningful only when `enabled`).
    pub max_replicas: u64,
    pub scale_ups: u64,
    pub scale_downs: u64,
    /// Modeled board draw at the autoscaler's last sample, watts.
    pub power_w: f64,
    /// Configured power budget, watts (0 = no budget).
    pub budget_w: f64,
    /// True while the power budget holds degraded routing latched.
    pub power_degraded: bool,
}

impl AutoscaleExport {
    /// The no-autoscaler export: all zeros, band collapsed to current.
    pub fn disabled() -> AutoscaleExport {
        AutoscaleExport::default()
    }
}

/// Render one scrape. `uptime_s` is the server's lifetime (the energy
/// power denominators), `trace_len`/`trace_dropped` the trace ring's
/// current state, `loop_gauges` a point-in-time view of the readiness
/// event loop, `autoscale` the autoscaler's counters (or
/// [`AutoscaleExport::disabled`]).
pub fn render_prometheus(
    snap: &MetricsSnapshot,
    health: &HealthReport,
    energy: &EnergyModel,
    uptime_s: f64,
    trace_len: u64,
    trace_dropped: u64,
    loop_gauges: &LoopGauges,
    autoscale: &AutoscaleExport,
) -> String {
    let mut out = String::with_capacity(4096);
    let pools = &snap.backends;

    family(&mut out, "edgemlp_uptime_seconds", "gauge", "Seconds since the server started.");
    sample(&mut out, "edgemlp_uptime_seconds", &[], uptime_s);

    family(&mut out, "edgemlp_degraded", "gauge", "1 while degraded-mode routing is active.");
    sample(&mut out, "edgemlp_degraded", &[], if health.degraded { 1.0 } else { 0.0 });

    family(
        &mut out,
        "edgemlp_degraded_transitions_total",
        "counter",
        "Degraded-mode flips (enter + exit) since startup.",
    );
    sample(&mut out, "edgemlp_degraded_transitions_total", &[], snap.degraded_transitions as f64);

    family(
        &mut out,
        "edgemlp_read_timeouts_total",
        "counter",
        "Connections closed by the per-frame read deadline.",
    );
    sample(&mut out, "edgemlp_read_timeouts_total", &[], health.read_timeouts as f64);

    family(
        &mut out,
        "edgemlp_busy_rejected_total",
        "counter",
        "Connections refused at the connection-pool limit (Busy).",
    );
    sample(&mut out, "edgemlp_busy_rejected_total", &[], snap.busy_rejected as f64);

    family(
        &mut out,
        "edgemlp_shed_total",
        "counter",
        "Requests shed by backpressure across all pools.",
    );
    sample(&mut out, "edgemlp_shed_total", &[], snap.rejected as f64);

    family(
        &mut out,
        "edgemlp_expired_total",
        "counter",
        "Requests answered Expired (admission + in-queue) across all pools.",
    );
    sample(&mut out, "edgemlp_expired_total", &[], snap.expired as f64);

    family(
        &mut out,
        "edgemlp_bad_requests_total",
        "counter",
        "Requests answered BadRequest, by cause.",
    );
    for (cause, n) in &snap.bad_requests {
        sample(&mut out, "edgemlp_bad_requests_total", &[("cause", cause)], *n as f64);
    }

    family(
        &mut out,
        "edgemlp_trace_buffer_events",
        "gauge",
        "Lifecycle events currently held in the trace ring.",
    );
    sample(&mut out, "edgemlp_trace_buffer_events", &[], trace_len as f64);

    family(
        &mut out,
        "edgemlp_trace_dropped_total",
        "counter",
        "Trace events dropped (oldest-first) because the ring was full.",
    );
    sample(&mut out, "edgemlp_trace_dropped_total", &[], trace_dropped as f64);

    family(
        &mut out,
        "edgemlp_static_power_watts",
        "gauge",
        "Modeled board static draw (server-wide, not per pool).",
    );
    sample(&mut out, "edgemlp_static_power_watts", &[], energy.static_w);

    // ---- readiness event loop ----
    family(
        &mut out,
        "edgemlp_loop_registered_connections",
        "gauge",
        "Sockets registered with the readiness event loop.",
    );
    sample(
        &mut out,
        "edgemlp_loop_registered_connections",
        &[],
        loop_gauges.registered_conns as f64,
    );

    family(
        &mut out,
        "edgemlp_loop_ready_events_total",
        "counter",
        "Readiness events delivered by the poller since startup.",
    );
    sample(&mut out, "edgemlp_loop_ready_events_total", &[], loop_gauges.ready_events as f64);

    family(
        &mut out,
        "edgemlp_loop_poll_ticks_total",
        "counter",
        "Poller wakeups (event batches + timer ticks) since startup.",
    );
    sample(&mut out, "edgemlp_loop_poll_ticks_total", &[], loop_gauges.poll_ticks as f64);

    family(
        &mut out,
        "edgemlp_loop_pending_writeback_bytes",
        "gauge",
        "Response bytes accepted from the coordinator but not yet flushed.",
    );
    sample(
        &mut out,
        "edgemlp_loop_pending_writeback_bytes",
        &[],
        loop_gauges.pending_writeback_bytes as f64,
    );

    family(
        &mut out,
        "edgemlp_loop_timer_wheel_depth",
        "gauge",
        "Live entries in the event loop's timer wheel.",
    );
    sample(&mut out, "edgemlp_loop_timer_wheel_depth", &[], loop_gauges.timer_depth as f64);

    // ---- per-pool counter families ----
    let pool_counter = |out: &mut String, name: &str, help: &str, f: &dyn Fn(&str) -> f64| {
        family(out, name, "counter", help);
        for pool in pools.keys() {
            sample(out, name, &[("pool", pool)], f(pool));
        }
    };
    pool_counter(&mut out, "edgemlp_pool_requests_total", "Requests served, per pool.", &|p| {
        pools[p].requests as f64
    });
    pool_counter(
        &mut out,
        "edgemlp_pool_samples_total",
        "Samples executed (batch members), per pool.",
        &|p| pools[p].batch_size_sum as f64,
    );
    pool_counter(&mut out, "edgemlp_pool_batches_total", "Batches executed, per pool.", &|p| {
        pools[p].batches as f64
    });
    pool_counter(&mut out, "edgemlp_pool_errors_total", "Failed requests, per pool.", &|p| {
        pools[p].errors as f64
    });
    pool_counter(&mut out, "edgemlp_pool_shed_total", "Requests shed, per pool.", &|p| {
        pools[p].shed as f64
    });
    pool_counter(&mut out, "edgemlp_pool_expired_total", "Requests expired, per pool.", &|p| {
        pools[p].expired as f64
    });

    family(
        &mut out,
        "edgemlp_pool_bytes_per_sample",
        "gauge",
        "Weight bytes the pool streams per served sample at its precision.",
    );
    for (pool, m) in pools {
        if m.bytes_per_sample > 0 {
            sample(
                &mut out,
                "edgemlp_pool_bytes_per_sample",
                &[("pool", pool)],
                m.bytes_per_sample as f64,
            );
        }
    }

    // ---- queue gauges (from the health view; names match pools) ----
    let health_gauge = |out: &mut String, name: &str, help: &str, f: &dyn Fn(usize) -> f64| {
        family(out, name, "gauge", help);
        for (i, p) in health.pools.iter().enumerate() {
            sample(out, name, &[("pool", &p.name)], f(i));
        }
    };
    health_gauge(&mut out, "edgemlp_pool_queue_depth", "Requests currently queued.", &|i| {
        health.pools[i].queue_depth as f64
    });
    health_gauge(&mut out, "edgemlp_pool_queue_capacity", "Configured queue bound.", &|i| {
        health.pools[i].queue_capacity as f64
    });
    health_gauge(&mut out, "edgemlp_pool_replicas", "Worker replicas draining the queue.", &|i| {
        health.pools[i].replicas as f64
    });

    // ---- autoscaler (families always present; zeros when disabled) ----
    health_gauge(
        &mut out,
        "edgemlp_pool_replicas_current",
        "Active worker replicas (the autoscaler's controlled variable).",
        &|i| health.pools[i].replicas as f64,
    );
    health_gauge(
        &mut out,
        "edgemlp_pool_replicas_min",
        "Autoscale replica floor (current replicas when not autoscaling).",
        &|i| {
            if autoscale.enabled {
                autoscale.min_replicas as f64
            } else {
                health.pools[i].replicas as f64
            }
        },
    );
    health_gauge(
        &mut out,
        "edgemlp_pool_replicas_max",
        "Autoscale replica ceiling (current replicas when not autoscaling).",
        &|i| {
            if autoscale.enabled {
                autoscale.max_replicas as f64
            } else {
                health.pools[i].replicas as f64
            }
        },
    );

    family(
        &mut out,
        "edgemlp_autoscale_scale_ups_total",
        "counter",
        "Replica-add actions taken by the autoscaler.",
    );
    sample(&mut out, "edgemlp_autoscale_scale_ups_total", &[], autoscale.scale_ups as f64);

    family(
        &mut out,
        "edgemlp_autoscale_scale_downs_total",
        "counter",
        "Replica-retire actions taken by the autoscaler.",
    );
    sample(&mut out, "edgemlp_autoscale_scale_downs_total", &[], autoscale.scale_downs as f64);

    family(
        &mut out,
        "edgemlp_autoscale_power_watts",
        "gauge",
        "Modeled board draw (static + windowed dynamic) at the last autoscale sample.",
    );
    sample(&mut out, "edgemlp_autoscale_power_watts", &[], autoscale.power_w);

    family(
        &mut out,
        "edgemlp_autoscale_power_budget_watts",
        "gauge",
        "Configured power budget (0 = no budget).",
    );
    sample(&mut out, "edgemlp_autoscale_power_budget_watts", &[], autoscale.budget_w);

    family(
        &mut out,
        "edgemlp_autoscale_power_degraded",
        "gauge",
        "1 while the power budget holds accuracy-for-power degradation latched.",
    );
    sample(
        &mut out,
        "edgemlp_autoscale_power_degraded",
        &[],
        if autoscale.power_degraded { 1.0 } else { 0.0 },
    );

    // ---- latency histogram (native Prometheus histogram format) ----
    family(
        &mut out,
        "edgemlp_request_latency_seconds",
        "histogram",
        "Per-request latency (enqueue to response), per pool.",
    );
    for (pool, m) in pools {
        for (le_us, cum) in m.latency.cumulative_buckets() {
            let le = format_us_as_s(le_us);
            sample(
                &mut out,
                "edgemlp_request_latency_seconds_bucket",
                &[("pool", pool), ("le", &le)],
                cum as f64,
            );
        }
        sample(
            &mut out,
            "edgemlp_request_latency_seconds_bucket",
            &[("pool", pool), ("le", "+Inf")],
            m.latency.count() as f64,
        );
        sample(&mut out, "edgemlp_request_latency_seconds_sum", &[("pool", pool)], m.latency.sum_s());
        sample(
            &mut out,
            "edgemlp_request_latency_seconds_count",
            &[("pool", pool)],
            m.latency.count() as f64,
        );
    }

    // ---- stage occupancy (stage-pipelined pools only) ----
    let stage_family = |out: &mut String, name: &str, ty: &str, help: &str, f: &dyn Fn(&str, usize) -> f64| {
        family(out, name, ty, help);
        for (pool, m) in pools {
            for (si, s) in m.stages.iter().enumerate() {
                sample(out, name, &[("pool", pool), ("stage", &s.label)], f(pool, si));
            }
        }
    };
    stage_family(&mut out, "edgemlp_stage_jobs_total", "counter", "Jobs a stage completed.", &|p, i| {
        pools[p].stages[i].processed as f64
    });
    stage_family(&mut out, "edgemlp_stage_failed_total", "counter", "Jobs a stage failed.", &|p, i| {
        pools[p].stages[i].failed as f64
    });
    stage_family(
        &mut out,
        "edgemlp_stage_busy_seconds_total",
        "counter",
        "Wall time a stage spent computing.",
        &|p, i| pools[p].stages[i].busy_s,
    );
    stage_family(
        &mut out,
        "edgemlp_stage_stall_in_seconds_total",
        "counter",
        "Wall time a stage waited for upstream input.",
        &|p, i| pools[p].stages[i].stall_in_s,
    );
    stage_family(
        &mut out,
        "edgemlp_stage_stall_out_seconds_total",
        "counter",
        "Wall time a stage blocked on a full downstream channel.",
        &|p, i| pools[p].stages[i].stall_out_s,
    );
    stage_family(
        &mut out,
        "edgemlp_stage_occupancy_ratio",
        "gauge",
        "Busy fraction of a stage's observed wall time.",
        &|p, i| pools[p].stages[i].occupancy(),
    );

    // ---- energy (activity model × accumulated CycleStats) ----
    let energies: Vec<(&String, super::energy::PoolEnergy)> =
        pools.iter().map(|(name, m)| (name, pool_energy(energy, m, uptime_s))).collect();
    let energy_family = |out: &mut String, name: &str, ty: &str, help: &str, f: &dyn Fn(&super::energy::PoolEnergy) -> f64| {
        family(out, name, ty, help);
        for (pool, e) in &energies {
            sample(out, name, &[("pool", pool)], f(e));
        }
    };
    energy_family(
        &mut out,
        "edgemlp_pool_energy_joules_total",
        "counter",
        "Modeled dynamic energy consumed by the pool's datapath.",
        &|e| e.dynamic_j,
    );
    energy_family(
        &mut out,
        "edgemlp_pool_energy_joules_per_request",
        "gauge",
        "Modeled dynamic joules per served request.",
        &|e| e.j_per_request,
    );
    energy_family(
        &mut out,
        "edgemlp_pool_energy_mj_per_sample",
        "gauge",
        "Modeled dynamic millijoules per executed sample.",
        &|e| e.mj_per_sample,
    );
    energy_family(
        &mut out,
        "edgemlp_pool_power_watts",
        "gauge",
        "Average modeled dynamic power over the server's lifetime.",
        &|e| e.avg_dynamic_w,
    );

    out
}

fn family(out: &mut String, name: &str, ty: &str, help: &str) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {ty}\n"));
}

fn sample(out: &mut String, name: &str, labels: &[(&str, &str)], value: f64) {
    out.push_str(name);
    if !labels.is_empty() {
        out.push('{');
        for (i, (k, v)) in labels.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("{k}=\"{}\"", escape_label(v)));
        }
        out.push('}');
    }
    out.push(' ');
    out.push_str(&format_value(value));
    out.push('\n');
}

/// Prometheus label-value escaping: backslash, double quote, newline.
fn escape_label(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Integral values render without a fraction; everything else uses
/// Rust's shortest round-trip float form (valid Prometheus floats).
fn format_value(v: f64) -> String {
    if v.is_finite() && v.fract() == 0.0 && v.abs() < 9.007_199_254_740_992e15 {
        format!("{v:.0}")
    } else {
        format!("{v}")
    }
}

/// Exact decimal rendering of a microsecond count as seconds, with
/// trailing zeros trimmed (`2 → "0.000002"`, `2097152 → "2.097152"`,
/// `2000000 → "2"`) — keeps histogram `le` bounds clean and stable.
fn format_us_as_s(us: u64) -> String {
    let whole = us / 1_000_000;
    let frac = us % 1_000_000;
    if frac == 0 {
        return format!("{whole}");
    }
    let mut s = format!("{whole}.{frac:06}");
    while s.ends_with('0') {
        s.pop();
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn format_us_trims_cleanly() {
        assert_eq!(format_us_as_s(2), "0.000002");
        assert_eq!(format_us_as_s(2_097_152), "2.097152");
        assert_eq!(format_us_as_s(2_000_000), "2");
        assert_eq!(format_us_as_s(1_048_576), "1.048576");
    }

    #[test]
    fn format_value_integral_vs_float() {
        assert_eq!(format_value(5.0), "5");
        assert_eq!(format_value(0.5), "0.5");
        assert_eq!(format_value(0.0), "0");
    }

    #[test]
    fn label_escaping() {
        assert_eq!(escape_label("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
    }
}
