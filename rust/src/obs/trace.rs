//! Bounded request-lifecycle trace recorder.
//!
//! A single process-wide ring buffer of timestamped events, shared as
//! `Arc<TraceRecorder>` by the connection layer, the coordinator, and
//! the stage pipelines. The hot-path cost is one atomic load when
//! tracing is off and one short mutex-protected ring push when it is
//! on — no allocation per event beyond an occasional `Arc<str>` clone
//! for the track label. When the ring is full the **oldest** event is
//! dropped and counted, so the buffer always holds the most recent
//! window of activity.
//!
//! [`TraceRecorder::export_chrome_json`] renders the ring as Chrome
//! trace-event JSON (the `{"traceEvents": [...]}` format loadable in
//! Perfetto / `chrome://tracing`): each distinct `(category, track)`
//! pair becomes one named thread row, spans become `ph:"X"` complete
//! events and point events become `ph:"i"` instants, so a pipeline
//! stall or an EDF inversion is visible as a timeline instead of being
//! inferred from counters. Schema documented in
//! `docs/observability.md`.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// One recorded lifecycle event. `dur_us: Some(_)` is a span (rendered
/// `ph:"X"`), `None` an instant (`ph:"i"`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceEvent {
    /// Coarse category: `"conn"`, `"queue"`, `"worker"`, `"stage"`.
    pub cat: &'static str,
    /// Event name: `"accept"`, `"decode"`, `"enqueue"`, `"queued"`,
    /// `"shed"`, `"expired"`, `"infer"`, `"run"`, `"writeback"`, …
    pub name: &'static str,
    /// Timeline row within the category (pool name, stage label);
    /// `None` collapses onto the category's own row.
    pub track: Option<Arc<str>>,
    /// Microseconds since the recorder's epoch.
    pub ts_us: u64,
    /// Span duration in microseconds; `None` for instants.
    pub dur_us: Option<u64>,
    /// The request id the event belongs to (0 when not applicable;
    /// stage events carry the job sequence number instead).
    pub request_id: u64,
}

/// Thread-shared bounded trace ring. Construct once per server via
/// [`TraceRecorder::new`] and clone the `Arc` into every layer.
#[derive(Debug)]
pub struct TraceRecorder {
    enabled: AtomicBool,
    dropped: AtomicU64,
    epoch: Instant,
    capacity: usize,
    ring: Mutex<VecDeque<TraceEvent>>,
}

impl TraceRecorder {
    /// A recorder holding at most `capacity` events. `capacity == 0`
    /// disables recording entirely (every `record` is one relaxed
    /// atomic load).
    pub fn new(capacity: usize) -> Arc<Self> {
        Arc::new(TraceRecorder {
            enabled: AtomicBool::new(capacity > 0),
            dropped: AtomicU64::new(0),
            epoch: Instant::now(),
            capacity,
            ring: Mutex::new(VecDeque::with_capacity(capacity.min(1024))),
        })
    }

    /// A permanently disabled recorder (for paths that require one).
    pub fn off() -> Arc<Self> {
        Self::new(0)
    }

    /// Whether events are currently being recorded. Call sites can use
    /// this to skip timestamp capture for span events entirely.
    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Configured ring capacity.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Microseconds since the recorder's epoch.
    #[inline]
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Convert an [`Instant`] (e.g. a request's `enqueued_at`) to
    /// microseconds on this recorder's timeline. Instants predating the
    /// epoch saturate to 0.
    pub fn instant_us(&self, at: Instant) -> u64 {
        at.saturating_duration_since(self.epoch).as_micros() as u64
    }

    /// Record a point event at the current time.
    pub fn instant(&self, cat: &'static str, name: &'static str, track: Option<Arc<str>>, request_id: u64) {
        if !self.enabled() {
            return;
        }
        self.push(TraceEvent { cat, name, track, ts_us: self.now_us(), dur_us: None, request_id });
    }

    /// Record a span that started at `start_us` (on this recorder's
    /// timeline) and ends now.
    pub fn span(&self, cat: &'static str, name: &'static str, track: Option<Arc<str>>, start_us: u64, request_id: u64) {
        if !self.enabled() {
            return;
        }
        let now = self.now_us();
        self.push(TraceEvent {
            cat,
            name,
            track,
            ts_us: start_us.min(now),
            dur_us: Some(now.saturating_sub(start_us)),
            request_id,
        });
    }

    /// Record a fully specified span.
    pub fn span_at(&self, cat: &'static str, name: &'static str, track: Option<Arc<str>>, start_us: u64, dur_us: u64, request_id: u64) {
        if !self.enabled() {
            return;
        }
        self.push(TraceEvent { cat, name, track, ts_us: start_us, dur_us: Some(dur_us), request_id });
    }

    fn push(&self, ev: TraceEvent) {
        let mut ring = self.ring.lock().unwrap();
        if ring.len() >= self.capacity {
            ring.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        ring.push_back(ev);
    }

    /// Events dropped because the ring was full (oldest-first).
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Events currently held.
    pub fn len(&self) -> usize {
        self.ring.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Copy out the current ring contents, oldest first.
    pub fn snapshot(&self) -> Vec<TraceEvent> {
        self.ring.lock().unwrap().iter().cloned().collect()
    }

    /// Render the ring as Chrome trace-event JSON. Each distinct
    /// `(cat, track)` pair becomes one named thread row (pid 1);
    /// `otherData.dropped_events` reports the overflow count.
    pub fn export_chrome_json(&self) -> String {
        let events = self.snapshot();
        let dropped = self.dropped();
        // Stable row assignment: sorted by (cat, track).
        let mut rows: BTreeMap<(String, String), u64> = BTreeMap::new();
        for ev in &events {
            let key = (ev.cat.to_string(), ev.track.as_deref().unwrap_or("").to_string());
            let next = rows.len() as u64 + 1;
            rows.entry(key).or_insert(next);
        }
        let mut out = String::with_capacity(events.len() * 96 + 256);
        out.push_str("{\"traceEvents\":[");
        let mut first = true;
        let mut emit = |s: String, first: &mut bool, out: &mut String| {
            if !*first {
                out.push(',');
            }
            *first = false;
            out.push_str(&s);
        };
        for ((cat, track), tid) in &rows {
            let label = if track.is_empty() { cat.clone() } else { format!("{cat} {track}") };
            emit(
                format!(
                    "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":1,\"tid\":{tid},\
                     \"args\":{{\"name\":\"{}\"}}}}",
                    escape_json(&label)
                ),
                &mut first,
                &mut out,
            );
        }
        for ev in &events {
            let key = (ev.cat.to_string(), ev.track.as_deref().unwrap_or("").to_string());
            let tid = rows[&key];
            match ev.dur_us {
                Some(dur) => emit(
                    format!(
                        "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"X\",\"pid\":1,\"tid\":{tid},\
                         \"ts\":{},\"dur\":{dur},\"args\":{{\"req\":{}}}}}",
                        escape_json(ev.name),
                        escape_json(ev.cat),
                        ev.ts_us,
                        ev.request_id
                    ),
                    &mut first,
                    &mut out,
                ),
                None => emit(
                    format!(
                        "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"i\",\"s\":\"t\",\"pid\":1,\
                         \"tid\":{tid},\"ts\":{},\"args\":{{\"req\":{}}}}}",
                        escape_json(ev.name),
                        escape_json(ev.cat),
                        ev.ts_us,
                        ev.request_id
                    ),
                    &mut first,
                    &mut out,
                ),
            }
        }
        out.push_str(&format!(
            "],\"displayTimeUnit\":\"ms\",\"otherData\":{{\"dropped_events\":\"{dropped}\"}}}}"
        ));
        out
    }
}

/// Escape a string for a JSON string literal (RFC 8259).
pub(crate) fn escape_json(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_drops_oldest_and_counts() {
        let t = TraceRecorder::new(4);
        for i in 0..6u64 {
            t.instant("conn", "accept", None, i);
        }
        assert_eq!(t.len(), 4);
        assert_eq!(t.dropped(), 2);
        let events = t.snapshot();
        // Events 0 and 1 fell off the front; 2..=5 remain in order.
        assert_eq!(events.iter().map(|e| e.request_id).collect::<Vec<_>>(), vec![2, 3, 4, 5]);
    }

    #[test]
    fn disabled_recorder_records_nothing() {
        let t = TraceRecorder::off();
        assert!(!t.enabled());
        t.instant("conn", "accept", None, 1);
        t.span("worker", "infer", None, 0, 1);
        assert_eq!(t.len(), 0);
        assert_eq!(t.dropped(), 0);
    }

    #[test]
    fn span_measures_forward_from_start() {
        let t = TraceRecorder::new(8);
        let start = t.now_us();
        std::thread::sleep(std::time::Duration::from_millis(2));
        t.span("worker", "infer", Some(Arc::from("cpu/default")), start, 7);
        let events = t.snapshot();
        assert_eq!(events.len(), 1);
        let ev = &events[0];
        assert_eq!(ev.request_id, 7);
        assert!(ev.dur_us.unwrap() >= 1_000, "{ev:?}");
        assert_eq!(ev.track.as_deref(), Some("cpu/default"));
    }

    #[test]
    fn chrome_export_has_rows_spans_and_instants() {
        let t = TraceRecorder::new(16);
        t.instant("queue", "enqueue", Some(Arc::from("cpu/default")), 1);
        t.span_at("worker", "infer", Some(Arc::from("cpu/default")), 10, 25, 1);
        t.instant("conn", "accept", None, 0);
        let json = t.export_chrome_json();
        assert!(json.starts_with("{\"traceEvents\":["), "{json}");
        assert!(json.ends_with('}'), "{json}");
        // One thread_name metadata row per distinct (cat, track).
        assert_eq!(json.matches("\"thread_name\"").count(), 3, "{json}");
        assert!(json.contains("\"name\":\"worker cpu/default\""), "{json}");
        assert!(json.contains("\"ph\":\"X\""), "{json}");
        assert!(json.contains("\"ph\":\"i\""), "{json}");
        assert!(json.contains("\"dur\":25"), "{json}");
        assert!(json.contains("\"dropped_events\":\"0\""), "{json}");
        // Structurally balanced (cheap well-formedness check; the CI
        // smoke job additionally json.load()s a live dump).
        assert_eq!(json.matches('{').count(), json.matches('}').count(), "{json}");
        assert_eq!(json.matches('[').count(), json.matches(']').count(), "{json}");
    }

    #[test]
    fn export_reports_dropped_count() {
        let t = TraceRecorder::new(2);
        for i in 0..5u64 {
            t.instant("conn", "accept", None, i);
        }
        let json = t.export_chrome_json();
        assert!(json.contains("\"dropped_events\":\"3\""), "{json}");
    }

    #[test]
    fn instant_us_saturates_before_epoch() {
        let t = TraceRecorder::new(2);
        let before = Instant::now() - std::time::Duration::from_secs(10);
        // An Instant captured before the recorder existed maps to 0,
        // not a panic or an underflow.
        assert_eq!(t.instant_us(before.min(t.epoch)), 0);
    }
}
