//! Minimal std-only HTTP sidecar for `GET /metrics` — enough of
//! HTTP/1.1 for a Prometheus scraper or `curl`, and nothing more: one
//! accept thread, one short-lived handler thread per request,
//! connection-close semantics, a small header cap and a read deadline
//! so a stalled scraper cannot pin the listener.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Longest request head (request line + headers) we accept.
const MAX_HEAD: usize = 8 * 1024;
/// A scraper that cannot finish its request head in this window is cut.
const READ_TIMEOUT: Duration = Duration::from_secs(5);

/// Handle to the running sidecar; dropping it stops the listener.
pub struct MetricsHttp {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl MetricsHttp {
    /// Bind `addr` (e.g. `"127.0.0.1:9100"`, port 0 for ephemeral) and
    /// serve `GET /metrics` with the text `render` produces per scrape.
    pub fn start(
        addr: &str,
        render: Arc<dyn Fn() -> String + Send + Sync>,
    ) -> std::io::Result<MetricsHttp> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_flag = stop.clone();
        let handle = std::thread::Builder::new()
            .name("metrics-http".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if stop_flag.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = conn else { continue };
                    let render = render.clone();
                    // Handler threads are short-lived (one response,
                    // close); detached is fine — shutdown only needs
                    // the listener gone.
                    let _ = std::thread::Builder::new()
                        .name("metrics-conn".into())
                        .spawn(move || handle(stream, &*render));
                }
            })
            .expect("spawn metrics-http thread");
        Ok(MetricsHttp { addr: local, stop, handle: Some(handle) })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the listener and join the accept thread.
    pub fn shutdown(mut self) {
        self.stop_inner();
    }

    fn stop_inner(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Wake the blocking accept with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for MetricsHttp {
    fn drop(&mut self) {
        if self.handle.is_some() {
            self.stop_inner();
        }
    }
}

fn handle(mut stream: TcpStream, render: &dyn Fn() -> String) {
    let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
    let _ = stream.set_nodelay(true);
    let Some(path) = read_request_path(&mut stream) else {
        let _ = respond(&mut stream, 400, "Bad Request", "malformed request\n", "text/plain");
        return;
    };
    match path.as_str() {
        "/metrics" => {
            let body = render();
            let _ = respond(
                &mut stream,
                200,
                "OK",
                &body,
                "text/plain; version=0.0.4; charset=utf-8",
            );
        }
        "/" => {
            let _ = respond(
                &mut stream,
                200,
                "OK",
                "edgemlp metrics sidecar — scrape /metrics\n",
                "text/plain; charset=utf-8",
            );
        }
        _ => {
            let _ = respond(&mut stream, 404, "Not Found", "not found\n", "text/plain");
        }
    }
}

/// Read up to the end of the request head and return the request-line
/// path for a GET; `None` for anything malformed, oversized, or not
/// GET.
fn read_request_path(stream: &mut TcpStream) -> Option<String> {
    let mut head = Vec::with_capacity(256);
    let mut buf = [0u8; 512];
    while !head.windows(4).any(|w| w == b"\r\n\r\n") {
        if head.len() > MAX_HEAD {
            return None;
        }
        match stream.read(&mut buf) {
            Ok(0) | Err(_) => return None,
            Ok(n) => head.extend_from_slice(&buf[..n]),
        }
    }
    let text = String::from_utf8_lossy(&head);
    let request_line = text.lines().next()?;
    let mut parts = request_line.split_whitespace();
    let method = parts.next()?;
    let path = parts.next()?;
    if method != "GET" {
        return None;
    }
    Some(path.to_string())
}

fn respond(
    stream: &mut TcpStream,
    code: u16,
    reason: &str,
    body: &str,
    content_type: &str,
) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {code} {reason}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn get(addr: SocketAddr, path: &str) -> (u16, String) {
        let mut stream = TcpStream::connect(addr).unwrap();
        stream
            .write_all(format!("GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").as_bytes())
            .unwrap();
        let mut resp = String::new();
        stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        stream.read_to_string(&mut resp).unwrap();
        let code: u16 = resp.split_whitespace().nth(1).unwrap().parse().unwrap();
        let body = resp.split("\r\n\r\n").nth(1).unwrap_or("").to_string();
        (code, body)
    }

    #[test]
    fn serves_metrics_and_404s_the_rest() {
        let http = MetricsHttp::start(
            "127.0.0.1:0",
            Arc::new(|| "edgemlp_up 1\n".to_string()),
        )
        .unwrap();
        let addr = http.local_addr();
        let (code, body) = get(addr, "/metrics");
        assert_eq!(code, 200);
        assert_eq!(body, "edgemlp_up 1\n");
        let (code, _) = get(addr, "/nope");
        assert_eq!(code, 404);
        let (code, _) = get(addr, "/");
        assert_eq!(code, 200);
        http.shutdown();
    }

    #[test]
    fn render_runs_per_scrape() {
        let hits = Arc::new(std::sync::atomic::AtomicU64::new(0));
        let h2 = hits.clone();
        let http = MetricsHttp::start(
            "127.0.0.1:0",
            Arc::new(move || format!("scrapes {}\n", h2.fetch_add(1, Ordering::SeqCst) + 1)),
        )
        .unwrap();
        let addr = http.local_addr();
        assert_eq!(get(addr, "/metrics").1, "scrapes 1\n");
        assert_eq!(get(addr, "/metrics").1, "scrapes 2\n");
        assert_eq!(hits.load(Ordering::SeqCst), 2);
        http.shutdown();
    }

    #[test]
    fn non_get_is_rejected_not_panicked() {
        let http =
            MetricsHttp::start("127.0.0.1:0", Arc::new(|| String::new())).unwrap();
        let addr = http.local_addr();
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.write_all(b"POST /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut resp = String::new();
        stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        stream.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.1 400"), "{resp}");
        // The sidecar survives.
        let (code, _) = get(addr, "/metrics");
        assert_eq!(code, 200);
        http.shutdown();
    }
}
