//! Per-pool energy accounting: the dormant activity-based
//! [`EnergyModel`] applied to the [`CycleStats`] that already flow from
//! the SPx backends into the serving metrics.
//!
//! Attribution rules (documented in `docs/observability.md`):
//!
//! * **Dynamic** energy is charged per pool from that pool's
//!   accumulated simulator events — it is exactly
//!   [`EnergyModel::dynamic_energy_j`] over the pool's aggregate
//!   `CycleStats`, so joules/request reported here are consistent with
//!   the model applied to the run's aggregate trace by construction.
//! * **Static** draw belongs to the board, not to any one pool;
//!   reporting `static_w × elapsed` per pool would multiply-count it.
//!   It is exposed once, server-wide, as `edgemlp_static_power_watts`.
//! * Pools without simulator stats (pure f32 CPU pools) report zero
//!   dynamic energy — the activity model covers the simulated SPx
//!   datapath only. That absence is itself the paper's comparison
//!   point, not a gap to paper over.

use crate::coordinator::metrics::{BackendMetrics, MetricsSnapshot};
use crate::fpga::power::EnergyModel;

/// Energy view of one pool over the server's lifetime.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PoolEnergy {
    /// Activity-based dynamic energy, joules.
    pub dynamic_j: f64,
    /// Joules per served request (0 when no requests).
    pub j_per_request: f64,
    /// Millijoules per sample (batch members; 0 when no samples).
    pub mj_per_sample: f64,
    /// Average dynamic power over `elapsed_s`, watts.
    pub avg_dynamic_w: f64,
}

/// Compute the energy view of one pool's metrics over `elapsed_s`
/// seconds of server lifetime.
pub fn pool_energy(model: &EnergyModel, m: &BackendMetrics, elapsed_s: f64) -> PoolEnergy {
    let dynamic_j = model.dynamic_energy_j(&m.cycle_stats);
    let per = |num: f64, den: u64| if den == 0 { 0.0 } else { num / den as f64 };
    PoolEnergy {
        dynamic_j,
        j_per_request: per(dynamic_j, m.requests),
        mj_per_sample: per(dynamic_j * 1e3, m.batch_size_sum),
        avg_dynamic_w: if elapsed_s > 0.0 { dynamic_j / elapsed_s } else { 0.0 },
    }
}

/// Human-oriented energy lines appended to the `Stats` opcode text:
/// one line per pool with nonzero simulator activity, plus the static
/// draw. Empty string when no pool has activity stats.
pub fn render_energy_text(model: &EnergyModel, snap: &MetricsSnapshot, elapsed_s: f64) -> String {
    let mut out = String::new();
    for (name, m) in &snap.backends {
        let e = pool_energy(model, m, elapsed_s);
        if e.dynamic_j <= 0.0 {
            continue;
        }
        out.push_str(&format!(
            "energy {name}: {:.6} J dynamic ({:.6} J/req, {:.4} mJ/sample, avg {:.4} W)\n",
            e.dynamic_j, e.j_per_request, e.mj_per_sample, e.avg_dynamic_w
        ));
    }
    if !out.is_empty() {
        out.push_str(&format!("energy static: {:.2} W board draw (not per-pool)\n", model.static_w));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::stats::CycleStats;

    fn pool_with(stats: CycleStats, requests: u64, samples: u64) -> BackendMetrics {
        BackendMetrics {
            requests,
            batch_size_sum: samples,
            cycle_stats: stats,
            ..Default::default()
        }
    }

    #[test]
    fn pool_energy_matches_model_exactly() {
        let model = EnergyModel::default_fpga();
        let stats = CycleStats { shifts: 1000, adds: 500, mults: 10, ..Default::default() };
        let m = pool_with(stats, 20, 40);
        let e = pool_energy(&model, &m, 2.0);
        let expect = model.dynamic_energy_j(&stats);
        assert!(expect > 0.0);
        assert_eq!(e.dynamic_j, expect);
        assert_eq!(e.j_per_request, expect / 20.0);
        assert_eq!(e.mj_per_sample, expect * 1e3 / 40.0);
        assert_eq!(e.avg_dynamic_w, expect / 2.0);
    }

    #[test]
    fn zero_denominators_defend() {
        let model = EnergyModel::default_fpga();
        let m = pool_with(CycleStats { shifts: 5, ..Default::default() }, 0, 0);
        let e = pool_energy(&model, &m, 0.0);
        assert!(e.dynamic_j > 0.0);
        assert_eq!(e.j_per_request, 0.0);
        assert_eq!(e.mj_per_sample, 0.0);
        assert_eq!(e.avg_dynamic_w, 0.0);
    }

    #[test]
    fn cpu_pools_report_zero_and_render_nothing() {
        let model = EnergyModel::default_fpga();
        let mut snap = MetricsSnapshot {
            backends: Default::default(),
            rejected: 0,
            expired: 0,
            degraded_transitions: 0,
            busy_rejected: 0,
            bad_requests: Default::default(),
        };
        snap.backends.insert("cpu/default".into(), pool_with(CycleStats::default(), 10, 10));
        assert_eq!(render_energy_text(&model, &snap, 1.0), "");
        // Add an active SPx pool: one energy line + the static line.
        snap.backends.insert(
            "fpga/default".into(),
            pool_with(CycleStats { macs: 100, shifts: 300, adds: 400, ..Default::default() }, 10, 10),
        );
        let text = render_energy_text(&model, &snap, 1.0);
        assert!(text.contains("energy fpga/default:"), "{text}");
        assert!(!text.contains("cpu/default"), "{text}");
        assert!(text.contains("energy static: 2.50 W"), "{text}");
    }
}
