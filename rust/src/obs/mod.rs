//! Observability layer: request lifecycle tracing, Prometheus
//! text-format exposition, and per-pool energy accounting.
//!
//! The serving stack already *measures* (per-pool counters, latency
//! histograms, stage occupancy, simulator cycle stats); this module is
//! how those measurements leave the process:
//!
//! * [`trace`] — a bounded ring-buffer [`trace::TraceRecorder`]
//!   capturing timestamped per-request events (accept → decode →
//!   admit/shed → enqueue → dequeue → infer → per-stage run →
//!   writeback), exportable as Chrome trace-event JSON for
//!   Perfetto / `chrome://tracing`.
//! * [`prometheus`] — renders a `MetricsSnapshot` + `HealthReport` +
//!   energy model as Prometheus text exposition format 0.0.4.
//! * [`http`] — a std-only `GET /metrics` sidecar listener.
//! * [`energy`] — applies the activity-based
//!   [`crate::fpga::power::EnergyModel`] to per-pool `CycleStats` for
//!   joules/request, mJ/sample, and average-watts figures.
//!
//! See `docs/observability.md` for the metric-family inventory, the
//! trace event schema, and the energy model's assumptions.

pub mod energy;
pub mod http;
pub mod prometheus;
pub mod trace;

pub use energy::{pool_energy, render_energy_text, PoolEnergy};
pub use http::MetricsHttp;
pub use prometheus::{render_prometheus, AutoscaleExport};
pub use trace::{TraceEvent, TraceRecorder};
