//! Uniform quantization (paper §3.2.A): equal-interval levels, including
//! the binary `{0, 1}` and ternary `{-1, 0, 1}` special cases the paper
//! cites as reducing multiplication to AND/OR logic.

use super::Codebook;

/// Symmetric uniform b-bit codebook: levels `k / (2^{b-1} - 1)` for
/// `k ∈ [-(2^{b-1}-1), 2^{b-1}-1]` — `2^b - 1` levels spanning `[-1, 1]`
/// with a representable 0 (the "restricted range" convention).
pub fn uniform(bits: u32) -> Codebook {
    assert!((2..=16).contains(&bits), "uniform bits must be in 2..=16, got {bits}");
    let half = (1i64 << (bits - 1)) - 1;
    let scale = 1.0 / half as f32;
    let levels = (-half..=half).map(|k| k as f32 * scale).collect();
    Codebook::new(levels, format!("uniform(b={bits})"))
}

/// Binary `{0, 1}` quantization (multiplication → AND).
pub fn binary() -> Codebook {
    // Codebook invariants require symmetry; the paper's {0,1} mapping is
    // handled as ternary-with-positive-data in practice, but we expose the
    // literal set for the ablation — extended to {-1,0,1}'s positive half
    // is NOT valid, so binary is represented as {-1, 0, 1} magnitudes with
    // the sign fixed positive at encode time. For codebook purposes the
    // symmetric closure is what matters:
    Codebook::new(vec![-1.0, 0.0, 1.0], "binary")
}

/// Ternary `{-1, 0, 1}` quantization (multiplication → sign logic).
pub fn ternary() -> Codebook {
    Codebook::new(vec![-1.0, 0.0, 1.0], "ternary")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{Calibration, QuantizedTensor};
    use crate::util::check::property;
    use crate::util::rng::Pcg32;

    #[test]
    fn uniform_level_count() {
        for b in 2..=8 {
            assert_eq!(uniform(b).len(), (1usize << b) - 1, "b={b}");
        }
    }

    #[test]
    fn uniform_levels_equally_spaced() {
        let cb = uniform(4);
        let ls = cb.levels();
        let step = ls[1] - ls[0];
        for w in ls.windows(2) {
            assert!((w[1] - w[0] - step).abs() < 1e-6);
        }
    }

    #[test]
    fn uniform_spans_unit_interval() {
        let cb = uniform(6);
        assert_eq!(cb.levels()[0], -1.0);
        assert_eq!(*cb.levels().last().unwrap(), 1.0);
    }

    #[test]
    fn ternary_is_three_levels() {
        assert_eq!(ternary().len(), 3);
    }

    #[test]
    fn uniform_quant_error_bounded_by_half_step() {
        // Property: for data within [-α, α], |x - Q(x)| ≤ step/2 · α.
        property("uniform error bound", 64, |rng: &mut Pcg32| {
            let bits = 2 + rng.index(7) as u32;
            let cb = uniform(bits);
            let step = cb.levels()[1] - cb.levels()[0];
            let data: Vec<f32> = (0..64).map(|_| rng.range(-1.0, 1.0) as f32).collect();
            let q = QuantizedTensor::encode(&cb, &data, &[64], Calibration::MaxAbs);
            let deq = q.decode();
            for (&x, &y) in data.iter().zip(&deq) {
                assert!(
                    (x - y).abs() <= step / 2.0 * q.alpha + 1e-6,
                    "bits={bits} x={x} y={y}"
                );
            }
        });
    }

    #[test]
    fn all_codebooks_validate() {
        for b in 2..=10 {
            uniform(b).validate().unwrap();
        }
        binary().validate().unwrap();
        ternary().validate().unwrap();
    }
}
