//! Per-vector-scaled low-bit integer quantization (VS-Quant after
//! Keller et al.; see also FantastIC4's 4-bit MLPs in PAPERS.md).
//!
//! Where [`super::spx`] reproduces the paper's non-uniform shift-add
//! levels, this module is the complementary *uniform* low-bit family:
//! int8 / int4 weights with an f32 scale per **row group** (a "vector"
//! of consecutive output rows). A small group recovers most of the
//! accuracy a single per-tensor scale loses at 4 bits, while keeping
//! the inner loop a pure integer dot product — the per-group scale is
//! applied once per output element, outside the k-loop.
//!
//! Scale selection reuses the [`super::calib`] machinery against the
//! matching symmetric [`super::uniform`] codebook (`uniform(8)` levels
//! are exactly `k/127`, `uniform(4)` exactly `k/7`), so `MaxAbs`,
//! `Percentile` and `MseSearch` all apply unchanged.
//!
//! The integer datapath is **exact**: products of two i8 values and
//! their i32 accumulation over any realistic fan-in cannot overflow or
//! round, so scalar and SIMD kernels agree bit-for-bit — the same
//! contract the SPx shift-add path pins (see `nn/kernels/vsq_batch.rs`
//! and the conformance suite).

use super::{calib, uniform::uniform, Calibration};

/// Largest representable magnitude for a symmetric `bits`-wide integer
/// format: 127 for int8, 7 for int4 (restricted range, representable 0).
pub fn qmax(bits: u8) -> i32 {
    assert!(bits == 8 || bits == 4, "vsq bits must be 8 or 4, got {bits}");
    (1i32 << (bits - 1)) - 1
}

/// A 2-D weight tensor quantized to int8 or int4 with one f32 scale per
/// group of `group_rows` consecutive rows.
///
/// Values are stored one-per-byte as `i8` regardless of `bits` (int4
/// values are clamped to `[-7, 7]`); [`bytes_total`](Self::bytes_total)
/// reports the *packed* footprint (two int4 codes per byte) so the
/// bandwidth accounting reflects what a packed deployment would move.
#[derive(Debug, Clone, PartialEq)]
pub struct VsqTensor {
    bits: u8,
    rows: usize,
    cols: usize,
    group_rows: usize,
    /// Row-major `rows × cols` integer codes.
    q: Vec<i8>,
    /// One scale per row group, `ceil(rows / group_rows)` entries.
    /// Dequantized weight = `q[r][c] as f32 * scales[r / group_rows]`.
    scales: Vec<f32>,
}

impl VsqTensor {
    /// Quantize a row-major `rows × cols` f32 matrix. Each group of
    /// `group_rows` rows gets its own `α` from `calibration`, mapped to
    /// the integer scale `α / qmax`; codes are round-half-away-from-zero
    /// with NaN → 0 (matching `fpga/pu.rs::to_fixed`'s convention).
    pub fn encode(
        bits: u8,
        group_rows: usize,
        data: &[f32],
        rows: usize,
        cols: usize,
        calibration: Calibration,
    ) -> Self {
        assert!(group_rows > 0, "group_rows must be positive");
        assert_eq!(data.len(), rows * cols, "data len != rows*cols");
        let qm = qmax(bits) as f32;
        let codebook = uniform(bits as u32);
        let ngroups = rows.div_ceil(group_rows.min(rows.max(1)));
        let mut scales = Vec::with_capacity(ngroups.max(1));
        let mut q = vec![0i8; data.len()];
        let mut g0 = 0usize;
        while g0 < rows {
            let g1 = (g0 + group_rows).min(rows);
            let slice = &data[g0 * cols..g1 * cols];
            let alpha = calib::pick_alpha(&codebook, slice, calibration);
            let scale = if alpha > 0.0 { alpha / qm } else { 0.0 };
            let inv = if scale > 0.0 { 1.0 / scale } else { 0.0 };
            for (dst, &w) in q[g0 * cols..g1 * cols].iter_mut().zip(slice) {
                let x = if w.is_finite() { w * inv } else { 0.0 };
                *dst = x.round().clamp(-qm, qm) as i8;
            }
            scales.push(scale);
            g0 = g1;
        }
        if rows == 0 {
            scales.push(0.0);
        }
        VsqTensor { bits, rows, cols, group_rows, q, scales }
    }

    /// Rebuild from parts (deserialization path); validates invariants.
    pub fn from_parts(
        bits: u8,
        rows: usize,
        cols: usize,
        group_rows: usize,
        q: Vec<i8>,
        scales: Vec<f32>,
    ) -> Result<Self, String> {
        if bits != 8 && bits != 4 {
            return Err(format!("vsq bits must be 8 or 4, got {bits}"));
        }
        if group_rows == 0 {
            return Err("group_rows must be positive".into());
        }
        if q.len() != rows * cols {
            return Err(format!("q len {} != rows*cols {}", q.len(), rows * cols));
        }
        let want = rows.div_ceil(group_rows).max(1);
        if scales.len() != want {
            return Err(format!("scales len {} != {} groups", scales.len(), want));
        }
        let qm = qmax(bits) as i8;
        if q.iter().any(|&v| v < -qm || v > qm) {
            return Err(format!("code outside [-{qm}, {qm}]"));
        }
        if scales.iter().any(|s| !s.is_finite() || *s < 0.0) {
            return Err("scale not finite or negative".into());
        }
        Ok(VsqTensor { bits, rows, cols, group_rows, q, scales })
    }

    pub fn bits(&self) -> u8 {
        self.bits
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn group_rows(&self) -> usize {
        self.group_rows
    }

    pub fn scales(&self) -> &[f32] {
        &self.scales
    }

    /// Row `r`'s integer codes (length `cols`).
    pub fn row(&self, r: usize) -> &[i8] {
        &self.q[r * self.cols..(r + 1) * self.cols]
    }

    /// The scale applied to row `r`'s dot products.
    pub fn scale_for_row(&self, r: usize) -> f32 {
        self.scales[r / self.group_rows]
    }

    /// Dequantize to row-major f32.
    pub fn decode(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.q.len());
        for r in 0..self.rows {
            let s = self.scale_for_row(r);
            out.extend(self.row(r).iter().map(|&v| v as f32 * s));
        }
        out
    }

    /// Packed weight bytes: one byte per int8 code, half a byte per
    /// int4 code, plus 4 bytes per group scale.
    pub fn bytes_total(&self) -> usize {
        let code_bytes = match self.bits {
            4 => self.q.len().div_ceil(2),
            _ => self.q.len(),
        };
        code_bytes + 4 * self.scales.len()
    }
}

/// Symmetric int8 activation quantization: `x → round(x · 127 / d_scale)`
/// clamped to `±127`, NaN/inf → 0. The dequantization step is
/// `d_scale / 127` — pair each dot product with
/// `w_scale · d_scale / 127` to recover f32 (see `vsq_batch`).
///
/// Scalar on every dispatch path by design: quantization order never
/// affects the integer codes, so path identity is structural.
pub fn quantize_data_i8_into(data: &[f32], d_scale: f32, out: &mut Vec<i8>) {
    out.clear();
    out.reserve(data.len());
    if !(d_scale.is_finite() && d_scale > 0.0) {
        out.resize(data.len(), 0);
        return;
    }
    let inv = 127.0 / d_scale;
    for &x in data {
        let v = if x.is_finite() { (x * inv).round().clamp(-127.0, 127.0) as i8 } else { 0 };
        out.push(v);
    }
}

/// The f32 step one data code represents: `d_scale / 127`.
pub fn data_step(d_scale: f32) -> f32 {
    d_scale / 127.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::property;
    use crate::util::rng::Pcg32;

    #[test]
    fn qmax_values() {
        assert_eq!(qmax(8), 127);
        assert_eq!(qmax(4), 7);
    }

    #[test]
    fn roundtrip_on_exact_levels() {
        // Data already on int8 grid with per-group max 1.27 / 2.54 —
        // encode/decode must be exact.
        let data = [1.27f32, -0.64, 0.0, 0.01, 2.54, -1.27, 0.02, -2.54];
        let t = VsqTensor::encode(8, 2, &data, 4, 2, Calibration::MaxAbs);
        let back = t.decode();
        for (a, b) in data.iter().zip(&back) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn group_scales_are_independent() {
        // Row group 0 spans [-1,1], group 1 spans [-100,100]; per-group
        // scales keep group 0's resolution fine.
        let data = [1.0f32, -0.5, 100.0, -50.0];
        let t = VsqTensor::encode(8, 1, &data, 2, 2, Calibration::MaxAbs);
        assert_eq!(t.scales().len(), 2);
        assert!((t.scale_for_row(0) - 1.0 / 127.0).abs() < 1e-9);
        assert!((t.scale_for_row(1) - 100.0 / 127.0).abs() < 1e-6);
        let back = t.decode();
        assert!((back[1] - -0.5).abs() < 0.005, "fine group kept resolution: {}", back[1]);
    }

    #[test]
    fn int4_codes_stay_in_range() {
        let mut rng = Pcg32::new(11);
        let data: Vec<f32> = (0..64).map(|_| rng.range(-3.0, 3.0) as f32).collect();
        let t = VsqTensor::encode(4, 4, &data, 8, 8, Calibration::MaxAbs);
        for r in 0..8 {
            for &v in t.row(r) {
                assert!((-7..=7).contains(&(v as i32)), "int4 code {v} out of range");
            }
        }
    }

    #[test]
    fn nan_and_zero_groups_are_safe() {
        let data = [f32::NAN, f32::INFINITY, 0.0, 0.0];
        let t = VsqTensor::encode(8, 2, &data, 2, 2, Calibration::MaxAbs);
        // NaN group calibrates to a NaN-free alpha only via max_abs fold
        // (NaN.abs().max folds to the other values); codes must be finite.
        for r in 0..2 {
            for &v in t.row(r) {
                assert!((-127..=127).contains(&(v as i32)));
            }
        }
        let zero = VsqTensor::encode(8, 2, &[0.0; 4], 2, 2, Calibration::MaxAbs);
        assert_eq!(zero.decode(), vec![0.0; 4]);
    }

    #[test]
    fn bytes_total_accounts_packing() {
        let data = vec![0.5f32; 128 * 10];
        let t8 = VsqTensor::encode(8, 16, &data, 128, 10, Calibration::MaxAbs);
        let t4 = VsqTensor::encode(4, 16, &data, 128, 10, Calibration::MaxAbs);
        assert_eq!(t8.bytes_total(), 128 * 10 + 4 * 8);
        assert_eq!(t4.bytes_total(), 128 * 10 / 2 + 4 * 8);
    }

    #[test]
    fn from_parts_validates() {
        assert!(VsqTensor::from_parts(8, 2, 2, 1, vec![0; 4], vec![0.1, 0.2]).is_ok());
        assert!(VsqTensor::from_parts(5, 2, 2, 1, vec![0; 4], vec![0.1, 0.2]).is_err());
        assert!(VsqTensor::from_parts(8, 2, 2, 1, vec![0; 3], vec![0.1, 0.2]).is_err());
        assert!(VsqTensor::from_parts(8, 2, 2, 1, vec![0; 4], vec![0.1]).is_err());
        assert!(VsqTensor::from_parts(4, 1, 2, 1, vec![8, 0], vec![0.1]).is_err());
        assert!(VsqTensor::from_parts(8, 2, 2, 1, vec![0; 4], vec![0.1, f32::NAN]).is_err());
    }

    #[test]
    fn data_quantizer_contract() {
        let mut out = Vec::new();
        quantize_data_i8_into(&[1.0, -1.0, 0.5, f32::NAN, 2.0], 1.0, &mut out);
        assert_eq!(out, vec![127, -127, 64, 0, 127]);
        quantize_data_i8_into(&[1.0, 2.0], 0.0, &mut out);
        assert_eq!(out, vec![0, 0]);
        quantize_data_i8_into(&[1.0, 2.0], f32::NAN, &mut out);
        assert_eq!(out, vec![0, 0]);
    }

    #[test]
    fn quant_error_bounded_by_half_step() {
        property("vsq error bound", 32, |rng: &mut Pcg32| {
            let bits = if rng.uniform() < 0.5 { 8u8 } else { 4 };
            let rows = 1 + rng.index(12);
            let cols = 1 + rng.index(24);
            let group = 1 + rng.index(rows);
            let data: Vec<f32> =
                (0..rows * cols).map(|_| rng.range(-2.0, 2.0) as f32).collect();
            let t = VsqTensor::encode(bits, group, &data, rows, cols, Calibration::MaxAbs);
            let back = t.decode();
            for r in 0..rows {
                let half_step = t.scale_for_row(r) / 2.0;
                for c in 0..cols {
                    let (x, y) = (data[r * cols + c], back[r * cols + c]);
                    assert!(
                        (x - y).abs() <= half_step + 1e-6,
                        "bits={bits} r={r} c={c} x={x} y={y}"
                    );
                }
            }
        });
    }
}
