//! Quantization error metrics used by the E4 ablation bench
//! (`benches/quant_ablation.rs`): MSE, SQNR, max error, and the tail-MSE
//! split that quantifies the paper's §3.2 claim about PoT's weakness at
//! the interval ends.

/// Mean squared error between `original` and `quantized`.
pub fn mse(original: &[f32], quantized: &[f32]) -> f64 {
    assert_eq!(original.len(), quantized.len());
    if original.is_empty() {
        return 0.0;
    }
    original
        .iter()
        .zip(quantized)
        .map(|(&a, &b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        / original.len() as f64
}

/// Signal-to-quantization-noise ratio in dB: `10·log10(Σx² / Σ(x-q)²)`.
/// Returns `f64::INFINITY` for exact reproduction.
pub fn sqnr_db(original: &[f32], quantized: &[f32]) -> f64 {
    assert_eq!(original.len(), quantized.len());
    let signal: f64 = original.iter().map(|&x| (x as f64).powi(2)).sum();
    let noise: f64 = original
        .iter()
        .zip(quantized)
        .map(|(&a, &b)| ((a - b) as f64).powi(2))
        .sum();
    if noise == 0.0 {
        f64::INFINITY
    } else {
        10.0 * (signal / noise).log10()
    }
}

/// Largest absolute elementwise error.
pub fn max_abs_err(original: &[f32], quantized: &[f32]) -> f32 {
    original
        .iter()
        .zip(quantized)
        .map(|(&a, &b)| (a - b).abs())
        .fold(0.0, f32::max)
}

/// MSE restricted to elements whose |x| exceeds `threshold · max|x|` —
/// the "tail" region where PoT levels are sparse. Returns `(tail_mse,
/// center_mse, tail_fraction)`.
pub fn tail_split_mse(
    original: &[f32],
    quantized: &[f32],
    threshold: f64,
) -> (f64, f64, f64) {
    assert_eq!(original.len(), quantized.len());
    let max = original.iter().fold(0.0f32, |m, &x| m.max(x.abs())) as f64;
    let cut = max * threshold;
    let (mut tail_sq, mut tail_n, mut center_sq, mut center_n) = (0.0f64, 0usize, 0.0f64, 0usize);
    for (&a, &b) in original.iter().zip(quantized) {
        let e = ((a - b) as f64).powi(2);
        if (a.abs() as f64) > cut {
            tail_sq += e;
            tail_n += 1;
        } else {
            center_sq += e;
            center_n += 1;
        }
    }
    let tail_mse = if tail_n > 0 { tail_sq / tail_n as f64 } else { 0.0 };
    let center_mse = if center_n > 0 { center_sq / center_n as f64 } else { 0.0 };
    (tail_mse, center_mse, tail_n as f64 / original.len() as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::spx::{SpxConfig, SpxTensor};
    use crate::quant::{fake_quantize, pot::pot, Calibration};
    use crate::util::rng::Pcg32;

    #[test]
    fn mse_zero_for_identical() {
        assert_eq!(mse(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
    }

    #[test]
    fn sqnr_infinite_for_identical() {
        assert!(sqnr_db(&[1.0, 2.0], &[1.0, 2.0]).is_infinite());
    }

    #[test]
    fn sqnr_decreases_with_noise() {
        let x = [1.0f32, -1.0, 0.5, -0.5];
        let small: Vec<f32> = x.iter().map(|v| v + 0.01).collect();
        let big: Vec<f32> = x.iter().map(|v| v + 0.1).collect();
        assert!(sqnr_db(&x, &small) > sqnr_db(&x, &big));
    }

    #[test]
    fn spx_beats_pot_in_the_tails() {
        // The paper's quantitative claim, as a unit test: at the same bit
        // budget, SP2's tail MSE on normal weights is lower than PoT's.
        let mut rng = Pcg32::new(2021);
        let data: Vec<f32> = (0..4096).map(|_| rng.normal() as f32 * 0.3).collect();
        let b = 5;
        let pot_q = fake_quantize(&pot(b), &data, Calibration::MaxAbs);
        let sp2 = SpxTensor::encode(&SpxConfig::sp2(b), &data, &[4096], Calibration::MaxAbs);
        let sp2_q = sp2.decode();
        let (pot_tail, _, _) = tail_split_mse(&data, &pot_q, 0.5);
        let (sp2_tail, _, _) = tail_split_mse(&data, &sp2_q, 0.5);
        assert!(
            sp2_tail < pot_tail,
            "sp2 tail mse {sp2_tail} should beat pot {pot_tail}"
        );
    }

    #[test]
    fn more_bits_less_error() {
        let mut rng = Pcg32::new(7);
        let data: Vec<f32> = (0..1024).map(|_| rng.normal() as f32).collect();
        let mut last = f64::INFINITY;
        for b in [3u32, 4, 5, 6, 7] {
            let q = SpxTensor::encode(&SpxConfig::sp2(b), &data, &[1024], Calibration::MaxAbs);
            let e = mse(&data, &q.decode());
            assert!(e <= last * 1.001, "b={b}: mse {e} > previous {last}");
            last = e;
        }
    }
}
