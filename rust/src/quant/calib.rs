//! Scale (`α`) calibration strategies for tensor quantization.
//!
//! The paper quantizes into `[-α, α]` (Eq 3.1) without specifying how α
//! is chosen; max-abs is the implicit choice and the default everywhere.
//! Percentile clipping and MSE search are provided for the ablation
//! benches (they matter once the weight distribution has outliers).

use super::{Calibration, Codebook};

/// Pick α for `data` under `calibration`.
pub fn pick_alpha(codebook: &Codebook, data: &[f32], calibration: Calibration) -> f32 {
    match calibration {
        Calibration::MaxAbs => max_abs(data),
        Calibration::Percentile(p) => percentile_abs(data, p),
        Calibration::MseSearch => mse_search(codebook, data),
    }
}

fn max_abs(data: &[f32]) -> f32 {
    data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
}

fn percentile_abs(data: &[f32], p: f64) -> f32 {
    if data.is_empty() {
        return 0.0;
    }
    let mut mags: Vec<f32> = data.iter().map(|x| x.abs()).collect();
    mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (mags.len() as f64 - 1.0)).round() as usize;
    mags[rank.min(mags.len() - 1)]
}

/// Quantization MSE of `data` at scale `alpha`.
fn quant_mse(codebook: &Codebook, data: &[f32], alpha: f32) -> f64 {
    if alpha <= 0.0 {
        return data.iter().map(|&x| (x as f64).powi(2)).sum::<f64>();
    }
    let inv = 1.0 / alpha;
    data.iter()
        .map(|&x| {
            let q = codebook.nearest((x * inv).clamp(-1.0, 1.0)).1 * alpha;
            ((x - q) as f64).powi(2)
        })
        .sum::<f64>()
}

/// Coarse-to-fine grid search over α ∈ [0.3, 1.2]·max_abs.
fn mse_search(codebook: &Codebook, data: &[f32]) -> f32 {
    let hi = max_abs(data);
    if hi == 0.0 {
        return 0.0;
    }
    let mut best = (f64::INFINITY, hi);
    for step in 0..=24 {
        let alpha = hi * (0.3 + 0.9 * step as f32 / 24.0);
        let mse = quant_mse(codebook, data, alpha);
        if mse < best.0 {
            best = (mse, alpha);
        }
    }
    // Refine around the winner.
    let center = best.1;
    for step in 0..=16 {
        let alpha = center * (0.92 + 0.16 * step as f32 / 16.0);
        let mse = quant_mse(codebook, data, alpha);
        if mse < best.0 {
            best = (mse, alpha);
        }
    }
    best.1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::uniform::uniform;
    use crate::util::check::property;

    #[test]
    fn max_abs_basic() {
        assert_eq!(max_abs(&[1.0, -3.0, 2.0]), 3.0);
        assert_eq!(max_abs(&[]), 0.0);
    }

    #[test]
    fn percentile_100_equals_max() {
        let data = [0.1f32, -0.9, 0.5];
        assert_eq!(percentile_abs(&data, 100.0), 0.9);
    }

    #[test]
    fn percentile_clips_outlier() {
        let mut data = vec![0.1f32; 999];
        data.push(100.0);
        let p = percentile_abs(&data, 99.0);
        assert!(p < 1.0, "p99 {p} should ignore the single outlier");
    }

    #[test]
    fn mse_search_never_worse_than_maxabs() {
        property("mse_search <= maxabs mse", 24, |rng| {
            let cb = uniform(4);
            // Heavy-tailed data: normal + occasional outlier.
            let data: Vec<f32> = (0..256)
                .map(|_| {
                    let x = rng.normal() as f32;
                    if rng.uniform() < 0.02 {
                        x * 10.0
                    } else {
                        x
                    }
                })
                .collect();
            let maxabs_mse = quant_mse(&cb, &data, max_abs(&data));
            let searched = mse_search(&cb, &data);
            let searched_mse = quant_mse(&cb, &data, searched);
            assert!(
                searched_mse <= maxabs_mse * (1.0 + 1e-9),
                "searched {searched_mse} > maxabs {maxabs_mse}"
            );
        });
    }

    #[test]
    fn zero_data_gives_zero_alpha() {
        let cb = uniform(4);
        assert_eq!(pick_alpha(&cb, &[0.0; 16], super::super::Calibration::MseSearch), 0.0);
        assert_eq!(pick_alpha(&cb, &[0.0; 16], super::super::Calibration::MaxAbs), 0.0);
    }
}
