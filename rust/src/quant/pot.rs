//! Power-of-Two (PoT) quantization — Eq 3.1 of the paper:
//!
//! ```text
//! Q(b, α) = α × {0, ±2^-(2^{b-1}-1), …, ±1/2, ±1}
//! ```
//!
//! Multiplication by a level is a pure shift (Eq 3.2), but the levels
//! crowd near 0 and thin out toward ±α — the "tail end" weakness that
//! SP2/SPx (see [`super::spx`]) address.

use super::Codebook;

/// PoT b-bit codebook: zero plus `±2^-k` for `k ∈ 0..2^{b-1}-1`,
/// i.e. `2^b - 1` levels (one code is the sign, one pattern is 0).
pub fn pot(bits: u32) -> Codebook {
    assert!((2..=6).contains(&bits), "pot bits must be in 2..=6, got {bits}");
    let max_exp = (1u32 << (bits - 1)) - 1; // 2^{b-1} - 1 magnitudes
    let mut levels = vec![0.0f32];
    for k in 0..max_exp {
        let mag = (2.0f32).powi(-(k as i32));
        levels.push(mag);
        levels.push(-mag);
    }
    Codebook::new(levels, format!("pot(b={bits})"))
}

/// Shift semantics of Eq 3.2 on a fixed-point accumulator: multiply a
/// Q(17.15) fixed-point value `q` by `2^{-k}` via an arithmetic right
/// shift. This is the primitive the FPGA simulator's PUs execute.
#[inline]
pub fn shift_mul_fixed(q: i32, k: u32) -> i32 {
    q >> k
}

/// Exact f32 multiplication by `±2^{-k}` via exponent arithmetic —
/// the software mirror of the shift (used to cross-check the simulator).
#[inline]
pub fn shift_mul_f32(x: f32, k: u32, negative: bool) -> f32 {
    let scaled = x * (2.0f32).powi(-(k as i32));
    if negative {
        -scaled
    } else {
        scaled
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::property;

    #[test]
    fn pot_level_count() {
        for b in 2..=6 {
            assert_eq!(pot(b).len(), (1usize << b) - 1, "b={b}");
        }
    }

    #[test]
    fn pot_contains_expected_levels_b3() {
        // b=3: max_exp = 3 → {0, ±1, ±1/2, ±1/4}.
        let cb = pot(3);
        let expect = [-1.0, -0.5, -0.25, 0.0, 0.25, 0.5, 1.0];
        assert_eq!(cb.levels(), &expect);
    }

    #[test]
    fn pot_tails_sparser_than_center() {
        // The §3.2.B complaint: gap near ±1 is much larger than near 0.
        let cb = pot(4);
        let tail_gap = cb.max_gap_in(0.5, 1.0);
        let center_gap = cb.max_gap_in(-0.05, 0.05);
        assert!(
            tail_gap > 4.0 * center_gap,
            "tail {tail_gap} vs center {center_gap}"
        );
    }

    #[test]
    fn shift_mul_fixed_matches_division() {
        property("fixed shift = /2^k", 128, |rng| {
            let q = (rng.next_u32() as i32) >> 8; // keep headroom
            let k = rng.index(8) as u32;
            // Arithmetic shift rounds toward -inf; compare against that.
            let expect = (q as i64).div_euclid(1i64 << k) as i32;
            assert_eq!(shift_mul_fixed(q, k), expect, "q={q} k={k}");
        });
    }

    #[test]
    fn shift_mul_f32_exact_for_pot_levels() {
        property("f32 shift exact", 64, |rng| {
            let x = rng.range(-1e3, 1e3) as f32;
            let k = rng.index(10) as u32;
            let neg = rng.uniform() < 0.5;
            let level = if neg { -(2.0f32).powi(-(k as i32)) } else { (2.0f32).powi(-(k as i32)) };
            // Multiplying by a power of two is exact in IEEE 754 (barring
            // underflow, impossible at these magnitudes).
            assert_eq!(shift_mul_f32(x, k, neg), x * level);
        });
    }

    #[test]
    fn pot_validates() {
        for b in 2..=6 {
            pot(b).validate().unwrap();
        }
    }
}
