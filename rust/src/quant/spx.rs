//! SP2 / SPx non-uniform quantization — Eq 3.3 / Eq 3.4 of the paper.
//!
//! A level is `±α · Σᵢ qᵢ` where each term `qᵢ` is either absent (0) or a
//! negative power of two `2^{-k}`, `k ∈ 1..2^{bᵢ}-1`, and the bit budget
//! is `b = 1 + Σ bᵢ` (one sign bit). `x = 1` degenerates to a PoT-like
//! scheme, `x = 2` is SP2 (Chang et al., HPCA'21), larger `x` is the
//! paper's extension: each extra term densifies the level set near the
//! interval tails at the cost of one more shift-add per MAC.
//!
//! Representation: a weight is a global sign plus one exponent code per
//! term (`0` = term absent, `k` = contribute `2^{-k}`). The level set is
//! normalized by its maximum sum so the [`Codebook`] spans `[-1, 1]`;
//! the residual scale `α / max_sum` is a single per-tensor f32 multiply
//! that hardware applies once at the output stage (the "quantized float
//! multiplication" of §3.1), so the per-MAC arithmetic stays shift-add.

use super::{Calibration, Codebook};

/// Static configuration: bit width of each of the `x` terms.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpxConfig {
    /// `bᵢ` for each term; `x = term_bits.len()`, `b = 1 + Σ bᵢ`.
    pub term_bits: Vec<u32>,
}

impl SpxConfig {
    pub fn new(term_bits: Vec<u32>) -> Self {
        assert!(!term_bits.is_empty(), "need at least one term");
        assert!(
            term_bits.iter().all(|&b| (1..=7).contains(&b)),
            "term bits must be in 1..=7: {term_bits:?}"
        );
        SpxConfig { term_bits }
    }

    /// SP2 with an even split of `b - 1` bits (paper Eq 3.3).
    pub fn sp2(total_bits: u32) -> Self {
        assert!(total_bits >= 3, "sp2 needs b >= 3");
        let payload = total_bits - 1;
        SpxConfig::new(vec![payload.div_ceil(2), payload / 2])
    }

    /// SPx with `x` equal terms from a total budget of `b` bits.
    pub fn spx(total_bits: u32, x: u32) -> Self {
        assert!(x >= 1 && total_bits > x, "need b > x >= 1");
        let payload = total_bits - 1;
        let base = payload / x;
        let extra = payload % x;
        let bits = (0..x).map(|i| base + u32::from(i < extra)).collect();
        SpxConfig::new(bits)
    }

    /// Number of terms `x`.
    pub fn num_terms(&self) -> usize {
        self.term_bits.len()
    }

    /// Total bit budget `b = 1 + Σ bᵢ`.
    pub fn total_bits(&self) -> u32 {
        1 + self.term_bits.iter().sum::<u32>()
    }

    /// Shift-adds one MAC costs under this scheme (hardware cost model).
    pub fn shifts_per_mac(&self) -> usize {
        self.num_terms()
    }
}

/// Exponent codes of one quantized weight: `codes[i] == 0` means term `i`
/// is absent, `codes[i] == k` means it contributes `2^{-k}`.
pub type SpxCode = Vec<u8>;

/// Magnitude of a code: `Σ 2^{-kᵢ}` (the *raw*, un-normalized sum).
pub fn code_magnitude(code: &[u8]) -> f32 {
    code.iter()
        .map(|&k| if k == 0 { 0.0 } else { (2.0f32).powi(-(k as i32)) })
        .sum()
}

/// An SPx level table: the normalized [`Codebook`] plus, for every level,
/// a canonical code (minimal active terms, then lexicographically least —
/// fewest shift-adds in hardware).
#[derive(Debug, Clone)]
pub struct SpxCodebook {
    pub config: SpxConfig,
    pub codebook: Codebook,
    /// `codes[i]` decodes (after normalization) to `codebook.levels()[i].abs()`
    /// — codes carry magnitudes only; the sign is stored separately.
    codes_by_level: Vec<SpxCode>,
    /// Largest raw sum — the normalization factor.
    pub max_sum: f32,
}

impl SpxCodebook {
    /// Enumerate all code combinations, dedupe magnitudes, normalize.
    pub fn build(config: SpxConfig) -> Self {
        // Enumerate the cartesian product of per-term code spaces.
        let mut sums: Vec<(f32, SpxCode)> = vec![(0.0, vec![0; config.num_terms()])];
        for (t, &bits) in config.term_bits.iter().enumerate() {
            let max_code = (1u32 << bits) - 1;
            let mut next = Vec::with_capacity(sums.len() * (max_code as usize + 1));
            for (sum, code) in &sums {
                for c in 0..=max_code {
                    let mut code2 = code.clone();
                    code2[t] = c as u8;
                    let add = if c == 0 { 0.0 } else { (2.0f32).powi(-(c as i32)) };
                    next.push((sum + add, code2));
                }
            }
            sums = next;
        }
        // Canonical code per distinct magnitude: fewest active terms, then
        // lexicographically least.
        let mut by_mag: std::collections::BTreeMap<u32, SpxCode> = Default::default();
        for (sum, code) in sums {
            let key = sum.to_bits(); // magnitudes are non-negative dyadics → bit-ordered
            let better = match by_mag.get(&key) {
                None => true,
                Some(old) => {
                    let active = |c: &SpxCode| c.iter().filter(|&&k| k != 0).count();
                    (active(&code), code.clone()) < (active(old), old.clone())
                }
            };
            if better {
                by_mag.insert(key, code);
            }
        }
        let max_sum = f32::from_bits(*by_mag.keys().last().unwrap());
        assert!(max_sum > 0.0, "degenerate SPx codebook");
        // Normalized symmetric level set; magnitudes only in codes_by_level.
        let mut levels = Vec::new();
        let mut mags: Vec<(f32, SpxCode)> = Vec::new();
        for (bits, code) in by_mag {
            let mag = f32::from_bits(bits);
            let norm = mag / max_sum;
            mags.push((norm, code));
            levels.push(norm);
            if norm > 0.0 {
                levels.push(-norm);
            }
        }
        let codebook = Codebook::new(
            levels,
            format!(
                "spx(b=[{}])",
                config
                    .term_bits
                    .iter()
                    .map(|b| b.to_string())
                    .collect::<Vec<_>>()
                    .join(",")
            ),
        );
        // Align codes with the positive half of the codebook.
        let mut codes_by_level = Vec::with_capacity(codebook.len());
        for &l in codebook.levels() {
            let mag = l.abs();
            let code = mags
                .iter()
                .find(|(m, _)| (*m - mag).abs() < 1e-12)
                .map(|(_, c)| c.clone())
                .expect("level without code");
            codes_by_level.push(code);
        }
        SpxCodebook { config, codebook, codes_by_level, max_sum }
    }

    /// Number of distinct levels.
    pub fn len(&self) -> usize {
        self.codebook.len()
    }

    pub fn is_empty(&self) -> bool {
        self.codebook.is_empty()
    }

    /// Canonical code for level index `i` (magnitude part).
    pub fn code_for_level(&self, i: usize) -> &SpxCode {
        &self.codes_by_level[i]
    }

    /// Decode a (sign, code) pair to the normalized level — the value the
    /// shift-add hardware reconstructs before the `α/max_sum` rescale.
    pub fn decode_code(&self, negative: bool, code: &[u8]) -> f32 {
        let mag = code_magnitude(code) / self.max_sum;
        if negative {
            -mag
        } else {
            mag
        }
    }
}

/// Guard bits of the simulator's fixed-point datapath (see
/// `fpga::pu`); the packed layout precomputes shift sums at this width.
pub const FIXED_GUARD_BITS: u32 = 15;

/// Element-major packed layout of an [`SpxTensor`]'s codes: one u32 per
/// element carrying the sign (bit 31) and up to four 7-bit exponent
/// codes — a single cache stream for the simulator's inner MAC loop
/// (the plane-major layout costs one stream per term; see
/// EXPERIMENTS.md §Perf).
#[derive(Debug, Clone)]
pub struct PackedCodes {
    /// Number of terms packed per word.
    pub x: usize,
    /// `words[e]`: bit 31 = negative, bits `7t..7t+7` = code of term t.
    pub words: Vec<u32>,
    /// Per row (for 2-D tensors): number of *active* (non-zero) codes —
    /// the data-dependent add count the stats charge per dot product.
    pub row_active_terms: Vec<u32>,
    /// Precomputed signed shift sum per element:
    /// `sign · Σ_{k≠0} 2^{G−k}` with `G = FIXED_GUARD_BITS`. Because
    /// `(d << G) >> k == d · 2^{G−k}` exactly whenever `k ≤ G`, a MAC
    /// collapses to one integer multiply by this value — bit-identical
    /// to the shift-add datapath.
    pub values: Vec<i64>,
    /// Per row: true iff every active code satisfies `k ≤ G`, i.e. the
    /// multiply fast path is exact for the whole row.
    pub row_fast: Vec<bool>,
    /// Elements per row (2-D tensors) or the whole tensor (1-D).
    pub cols: usize,
}

impl PackedCodes {
    /// Number of packed rows.
    pub fn rows(&self) -> usize {
        self.row_fast.len()
    }

    /// Precomputed signed shift sums of row `r` (the multiply fast
    /// path's operand stream).
    pub fn row_values(&self, r: usize) -> &[i64] {
        &self.values[r * self.cols..(r + 1) * self.cols]
    }

    /// Packed sign+code words of row `r` (the shift fallback's stream).
    pub fn row_words(&self, r: usize) -> &[u32] {
        &self.words[r * self.cols..(r + 1) * self.cols]
    }
}

/// A tensor quantized under SPx: hardware-ready planes of exponent codes.
#[derive(Debug, Clone)]
pub struct SpxTensor {
    pub config: SpxConfig,
    pub shape: Vec<usize>,
    /// `signs[e]` ∈ {+1, -1} per element.
    pub signs: Vec<i8>,
    /// `planes[t][e]` = exponent code of term `t` for element `e`.
    pub planes: Vec<Vec<u8>>,
    /// Output-stage scale: `α / max_sum`.
    pub scale: f32,
    /// Level index per element (for fast table-based decode).
    pub indices: Vec<u16>,
    /// The level table this tensor was encoded against.
    pub table: SpxCodebook,
    /// Lazily built packed layout (see [`PackedCodes`]).
    packed: once_cell::sync::OnceCell<PackedCodes>,
}

impl SpxTensor {
    /// Quantize `data` under `config`.
    pub fn encode(
        config: &SpxConfig,
        data: &[f32],
        shape: &[usize],
        calibration: Calibration,
    ) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        let table = SpxCodebook::build(config.clone());
        let alpha = super::calib::pick_alpha(&table.codebook, data, calibration);
        let inv = if alpha > 0.0 { 1.0 / alpha } else { 0.0 };
        let x = config.num_terms();
        let mut signs = Vec::with_capacity(data.len());
        let mut planes = vec![Vec::with_capacity(data.len()); x];
        let mut indices = Vec::with_capacity(data.len());
        for &w in data {
            let normalized = (w * inv).clamp(-1.0, 1.0);
            let (idx, level) = table.codebook.nearest(normalized);
            let code = table.code_for_level(idx).clone();
            signs.push(if level < 0.0 { -1 } else { 1 });
            for (t, plane) in planes.iter_mut().enumerate() {
                plane.push(code[t]);
            }
            indices.push(idx as u16);
        }
        SpxTensor {
            config: config.clone(),
            shape: shape.to_vec(),
            signs,
            planes,
            scale: alpha / table.max_sum,
            indices,
            table,
            packed: once_cell::sync::OnceCell::new(),
        }
    }

    /// Rebuild a tensor from persisted parts — the form the serving
    /// model registry stores in EMLP blobs: per-element level indices
    /// plus the output-stage scale. Signs and code planes are re-derived
    /// from the canonical codebook, so a reloaded tensor decodes
    /// bit-identically to the one that was saved (pinned by a test).
    pub fn from_parts(
        config: &SpxConfig,
        shape: &[usize],
        indices: Vec<u16>,
        scale: f32,
    ) -> Result<Self, String> {
        let numel: usize = shape.iter().product();
        if indices.len() != numel {
            return Err(format!("{} indices for shape {shape:?}", indices.len()));
        }
        let table = SpxCodebook::build(config.clone());
        let x = config.num_terms();
        let mut signs = Vec::with_capacity(numel);
        let mut planes = vec![Vec::with_capacity(numel); x];
        for &idx in &indices {
            let idx = idx as usize;
            if idx >= table.len() {
                return Err(format!(
                    "level index {idx} out of range (codebook has {})",
                    table.len()
                ));
            }
            let level = table.codebook.levels()[idx];
            signs.push(if level < 0.0 { -1 } else { 1 });
            let code = table.code_for_level(idx);
            for (t, plane) in planes.iter_mut().enumerate() {
                plane.push(code[t]);
            }
        }
        Ok(SpxTensor {
            config: config.clone(),
            shape: shape.to_vec(),
            signs,
            planes,
            scale,
            indices,
            table,
            packed: once_cell::sync::OnceCell::new(),
        })
    }

    /// Element-major packed codes (built once, cached). Requires
    /// `x <= 4` and codes < 128, which every valid [`SpxConfig`]
    /// satisfies for the configurations this crate constructs.
    pub fn packed(&self) -> &PackedCodes {
        self.packed.get_or_init(|| {
            let x = self.planes.len();
            assert!(x <= 4, "packed layout supports up to 4 terms, got {x}");
            let numel = self.signs.len();
            let g = FIXED_GUARD_BITS;
            let mut words = Vec::with_capacity(numel);
            let mut values = Vec::with_capacity(numel);
            let mut elem_fast = vec![true; numel];
            for e in 0..numel {
                let negative = self.signs[e] < 0;
                let mut w = if negative { 1u32 << 31 } else { 0 };
                let mut v = 0i64;
                for (t, plane) in self.planes.iter().enumerate() {
                    let k = plane[e] as u32;
                    debug_assert!(k < 128);
                    w |= k << (7 * t);
                    if k != 0 {
                        if k <= g {
                            v += 1i64 << (g - k);
                        } else {
                            elem_fast[e] = false;
                        }
                    }
                }
                words.push(w);
                values.push(if negative { -v } else { v });
            }
            // Per-row aggregates (2-D) or the whole tensor as one row.
            let (rows, cols) = if self.shape.len() == 2 {
                (self.shape[0], self.shape[1])
            } else {
                (1, numel)
            };
            let mut row_active_terms = Vec::with_capacity(rows);
            let mut row_fast = Vec::with_capacity(rows);
            for r in 0..rows {
                let mut active = 0u32;
                let mut fast = true;
                for e in r * cols..(r + 1) * cols {
                    for plane in &self.planes {
                        active += u32::from(plane[e] != 0);
                    }
                    fast &= elem_fast[e];
                }
                row_active_terms.push(active);
                row_fast.push(fast);
            }
            PackedCodes { x, words, row_active_terms, values, row_fast, cols }
        })
    }

    /// Dequantize via the level table (reference path).
    pub fn decode(&self) -> Vec<f32> {
        let alpha = self.scale * self.table.max_sum;
        self.indices
            .iter()
            .map(|&i| self.table.codebook.levels()[i as usize] * alpha)
            .collect()
    }

    /// Dequantize via the shift-add path (hardware semantics): per element
    /// `sign · (Σ 2^{-kᵢ}) · scale`. Property tests pin this equal (to f32
    /// rounding) to [`SpxTensor::decode`].
    pub fn decode_shift_add(&self) -> Vec<f32> {
        (0..self.signs.len())
            .map(|e| {
                let mut sum = 0.0f32;
                for plane in &self.planes {
                    let k = plane[e];
                    if k != 0 {
                        sum += (2.0f32).powi(-(k as i32));
                    }
                }
                let v = sum * self.scale;
                if self.signs[e] < 0 {
                    -v
                } else {
                    v
                }
            })
            .collect()
    }

    pub fn numel(&self) -> usize {
        self.signs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Calibration;
    use crate::util::check::{assert_allclose, property};

    #[test]
    fn from_parts_rebuilds_encode_bitwise() {
        let mut rng = crate::util::rng::Pcg32::new(31);
        let config = SpxConfig::spx(6, 2);
        let data: Vec<f32> = (0..40).map(|_| rng.range(-2.0, 2.0) as f32).collect();
        let t = SpxTensor::encode(&config, &data, &[8, 5], Calibration::MaxAbs);
        let back =
            SpxTensor::from_parts(&config, &t.shape, t.indices.clone(), t.scale).unwrap();
        assert_eq!(back.signs, t.signs);
        assert_eq!(back.planes, t.planes);
        assert_eq!(back.decode(), t.decode());
        assert_eq!(back.decode_shift_add(), t.decode_shift_add());
        assert_eq!(back.packed().words, t.packed().words);
    }

    #[test]
    fn from_parts_rejects_bad_parts() {
        let config = SpxConfig::sp2(5);
        // Wrong element count.
        assert!(SpxTensor::from_parts(&config, &[2, 2], vec![0; 3], 1.0).is_err());
        // Out-of-range level index.
        assert!(SpxTensor::from_parts(&config, &[1], vec![u16::MAX], 1.0).is_err());
    }

    #[test]
    fn sp2_split() {
        assert_eq!(SpxConfig::sp2(5).term_bits, vec![2, 2]);
        assert_eq!(SpxConfig::sp2(6).term_bits, vec![3, 2]);
    }

    #[test]
    fn spx_split() {
        assert_eq!(SpxConfig::spx(7, 3).term_bits, vec![2, 2, 2]);
        assert_eq!(SpxConfig::spx(8, 3).term_bits, vec![3, 2, 2]);
        assert_eq!(SpxConfig::spx(4, 1).term_bits, vec![3]);
    }

    #[test]
    fn total_bits_roundtrip() {
        for b in 3..=8 {
            for x in 1..=3 {
                if b > x {
                    assert_eq!(SpxConfig::spx(b, x).total_bits(), b, "b={b} x={x}");
                }
            }
        }
    }

    #[test]
    fn sp2_codebook_matches_eq33_manually() {
        // b=3 → b1=b2=1 → qᵢ ∈ {0, 1/2} → raw sums {0, 1/2, 1}.
        let t = SpxCodebook::build(SpxConfig::new(vec![1, 1]));
        assert_eq!(t.max_sum, 1.0);
        assert_eq!(t.codebook.levels(), &[-1.0, -0.5, 0.0, 0.5, 1.0]);
    }

    #[test]
    fn canonical_code_prefers_fewer_terms() {
        // Magnitude 1/2 is reachable as (2^-1, absent) and (2^-2, 2^-2);
        // the canonical code must be the single-term one.
        let t = SpxCodebook::build(SpxConfig::new(vec![2, 2]));
        let idx = t.codebook.levels().iter().position(|&l| l == 0.5).unwrap();
        let code = t.code_for_level(idx);
        assert_eq!(code.iter().filter(|&&k| k != 0).count(), 1, "code {code:?}");
    }

    #[test]
    fn spx_denser_tails_than_pot_at_same_bits() {
        // The paper's core claim (§3.2): at the same bit budget, SP2 has
        // more levels near the interval ends than PoT.
        let pot = crate::quant::pot::pot(5);
        let sp2 = SpxCodebook::build(SpxConfig::sp2(5)).codebook;
        let pot_tail = pot.levels().iter().filter(|l| l.abs() > 0.5).count();
        let sp2_tail = sp2.levels().iter().filter(|l| l.abs() > 0.5).count();
        assert!(
            sp2_tail > pot_tail,
            "sp2 tail levels {sp2_tail} <= pot {pot_tail}"
        );
        // And the largest tail gap shrinks.
        assert!(sp2.max_gap_in(0.5, 1.0) < pot.max_gap_in(0.5, 1.0));
    }

    #[test]
    fn more_terms_denser_tails() {
        // Splitting the bit budget across more terms *reduces* the total
        // level count (combinations collide) but *increases* resolution
        // at the interval tails — Eq 3.4's "more choices at the two tail
        // ends". Count normalized levels with |l| > 0.5:
        // ends". Resolution metric: the largest gap between adjacent
        // levels in the outer half of the interval shrinks with x.
        let tail_gap = |x: u32| {
            SpxCodebook::build(SpxConfig::spx(7, x)).codebook.max_gap_in(0.5, 1.0)
        };
        let (g1, g2, g3) = (tail_gap(1), tail_gap(2), tail_gap(3));
        assert!(g2 < g1, "x=2 tail gap {g2} vs x=1 {g1}");
        assert!(g3 < g2, "x=3 tail gap {g3} vs x=2 {g2}");
        // And level *count* in the tail grows from x=1 to x=2.
        let tail_count = |x: u32| {
            SpxCodebook::build(SpxConfig::spx(7, x))
                .codebook
                .levels()
                .iter()
                .filter(|l| l.abs() > 0.5)
                .count()
        };
        assert!(tail_count(2) > tail_count(1));
    }

    #[test]
    fn decode_paths_agree() {
        property("table decode == shift-add decode", 48, |rng| {
            let x = 1 + rng.index(3) as u32;
            let b = (x + 2) + rng.index(3) as u32;
            let cfg = SpxConfig::spx(b, x);
            let data: Vec<f32> = (0..128).map(|_| rng.normal() as f32).collect();
            let t = SpxTensor::encode(&cfg, &data, &[128], Calibration::MaxAbs);
            assert_allclose(&t.decode_shift_add(), &t.decode(), 1e-6, 1e-5);
        });
    }

    #[test]
    fn decode_exact_when_max_sum_is_pow2() {
        // x=2 → max_sum = 1.0 → both decode paths are bit-identical.
        let cfg = SpxConfig::sp2(6);
        let data: Vec<f32> = (0..64).map(|i| ((i as f32) - 32.0) / 17.0).collect();
        let t = SpxTensor::encode(&cfg, &data, &[64], Calibration::MaxAbs);
        assert_eq!(t.table.max_sum, 1.0);
        assert_eq!(t.decode(), t.decode_shift_add());
    }

    #[test]
    fn quantization_is_idempotent() {
        property("Q(Q(w)) == Q(w)", 32, |rng| {
            let cfg = SpxConfig::sp2(5);
            let data: Vec<f32> = (0..64).map(|_| rng.range(-3.0, 3.0) as f32).collect();
            let t1 = SpxTensor::encode(&cfg, &data, &[64], Calibration::MaxAbs);
            let once = t1.decode();
            let t2 = SpxTensor::encode(&cfg, &once, &[64], Calibration::MaxAbs);
            assert_allclose(&t2.decode(), &once, 1e-7, 1e-6);
        });
    }

    #[test]
    fn planes_shape_matches_config() {
        let cfg = SpxConfig::spx(7, 3);
        let data = vec![0.5f32; 10];
        let t = SpxTensor::encode(&cfg, &data, &[2, 5], Calibration::MaxAbs);
        assert_eq!(t.planes.len(), 3);
        assert!(t.planes.iter().all(|p| p.len() == 10));
        assert_eq!(t.numel(), 10);
    }

    #[test]
    fn packed_row_accessors_match_layout() {
        let cfg = SpxConfig::sp2(5);
        let data: Vec<f32> = (0..12).map(|i| (i as f32 - 6.0) / 6.0).collect();
        let t = SpxTensor::encode(&cfg, &data, &[3, 4], Calibration::MaxAbs);
        let p = t.packed();
        assert_eq!((p.rows(), p.cols), (3, 4));
        for r in 0..3 {
            assert_eq!(p.row_values(r), &p.values[r * 4..(r + 1) * 4]);
            assert_eq!(p.row_words(r), &p.words[r * 4..(r + 1) * 4]);
        }
    }

    #[test]
    fn all_spx_codebooks_validate() {
        for b in 3..=8u32 {
            for x in 1..=3u32 {
                if b > x {
                    let t = SpxCodebook::build(SpxConfig::spx(b, x));
                    t.codebook.validate().unwrap();
                }
            }
        }
    }
}
