//! Quantization schemes from the paper's §3.2: uniform (binary / ternary /
//! b-bit), Power-of-Two (PoT, Eq 3.1), SP2 (Eq 3.3, after Chang et al.
//! HPCA'21) and the paper's generalized **SPx** (Eq 3.4) where a level is
//! `±α·Σᵢ qᵢ` with each `qᵢ` a (possibly absent) negative power of two.
//!
//! Everything funnels through a [`Codebook`]: a sorted set of *normalized*
//! levels in `[-1, 1]`. Encoding a tensor picks a scale `α` (see [`calib`]),
//! normalizes, and maps each weight to its nearest level; decoding is a
//! table lookup times `α`. SPx codebooks additionally carry the per-level
//! shift decomposition ([`spx::SpxCode`]) that the FPGA simulator's
//! shift-add MACs and the Pallas kernel's exponent-field decode both use —
//! bit-identical by construction, which the property tests pin down.
//!
//! [`vsq`] is the complementary *uniform* low-bit family: int8/int4 weight
//! codes with per-row-group f32 scales (VS-Quant), feeding the SIMD integer
//! dot kernels instead of the codebook machinery.

pub mod calib;
pub mod error;
pub mod pot;
pub mod spx;
pub mod uniform;
pub mod vsq;

use crate::util::serde::NamedTensor;

/// A sorted table of normalized quantization levels in `[-1, 1]`.
///
/// Invariants (checked in debug builds and by property tests):
/// levels are strictly increasing, symmetric around 0, contain 0, and
/// `|level| <= 1`.
#[derive(Debug, Clone, PartialEq)]
pub struct Codebook {
    levels: Vec<f32>,
    /// Human-readable scheme tag, e.g. `"pot(b=4)"` or `"spx(b=[2,2])"`.
    pub scheme: String,
}

impl Codebook {
    /// Build from raw levels; sorts, dedupes, and validates.
    pub fn new(mut levels: Vec<f32>, scheme: impl Into<String>) -> Self {
        levels.sort_by(|a, b| a.partial_cmp(b).unwrap());
        levels.dedup();
        let cb = Codebook { levels, scheme: scheme.into() };
        debug_assert!(cb.validate().is_ok(), "invalid codebook: {:?}", cb.validate());
        cb
    }

    /// Check the codebook invariants; returns a description of the first
    /// violation. Used by property tests across all schemes.
    pub fn validate(&self) -> Result<(), String> {
        if self.levels.is_empty() {
            return Err("empty codebook".into());
        }
        if !self.levels.contains(&0.0) {
            return Err("codebook lacks 0".into());
        }
        for w in self.levels.windows(2) {
            if w[0] >= w[1] {
                return Err(format!("levels not strictly increasing at {} >= {}", w[0], w[1]));
            }
        }
        for &l in &self.levels {
            if !(-1.0..=1.0).contains(&l) {
                return Err(format!("level {l} outside [-1,1]"));
            }
            // Symmetry: -l must also be a level.
            if self.nearest(-l).1 != -l {
                return Err(format!("level {l} has no negative counterpart"));
            }
        }
        Ok(())
    }

    /// Number of levels.
    pub fn len(&self) -> usize {
        self.levels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.levels.is_empty()
    }

    /// The sorted normalized levels.
    pub fn levels(&self) -> &[f32] {
        &self.levels
    }

    /// Nearest level to `x` (clamping outside `[-1,1]`): returns
    /// `(index, level)`. Ties resolve to the lower level, matching the
    /// python mirror (`python/compile/quant.py`).
    pub fn nearest(&self, x: f32) -> (usize, f32) {
        let ls = &self.levels;
        // Binary search for the insertion point.
        let mut lo = 0usize;
        let mut hi = ls.len();
        while lo < hi {
            let mid = (lo + hi) / 2;
            if ls[mid] < x {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        if lo == 0 {
            return (0, ls[0]);
        }
        if lo == ls.len() {
            return (ls.len() - 1, ls[ls.len() - 1]);
        }
        let (below, above) = (ls[lo - 1], ls[lo]);
        if (x - below) <= (above - x) {
            (lo - 1, below)
        } else {
            (lo, above)
        }
    }

    /// Fraction of levels whose magnitude exceeds `threshold` — the
    /// paper's "tail end" density argument for SPx (§3.2.B).
    pub fn tail_density(&self, threshold: f32) -> f64 {
        let tail = self.levels.iter().filter(|l| l.abs() > threshold).count();
        tail as f64 / self.levels.len() as f64
    }

    /// Largest gap between adjacent levels inside `[lo, hi]` — resolution
    /// metric used by the quant ablation bench.
    pub fn max_gap_in(&self, lo: f32, hi: f32) -> f32 {
        self.levels
            .windows(2)
            .filter(|w| w[0] >= lo && w[1] <= hi)
            .map(|w| w[1] - w[0])
            .fold(0.0, f32::max)
    }
}

/// How the scale `α` is chosen when encoding a tensor.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Calibration {
    /// `α = max |w|` — the paper's implicit choice (levels span `[-α, α]`).
    MaxAbs,
    /// `α = p`-th percentile of `|w|` (clips outliers).
    Percentile(f64),
    /// Grid-search `α` minimizing quantization MSE.
    MseSearch,
}

/// A tensor quantized against a [`Codebook`]: per-element level indices
/// plus the scale. `decode()` reproduces the dequantized f32 values that
/// every backend (rust CPU, FPGA sim, XLA artifact) must agree on.
#[derive(Debug, Clone)]
pub struct QuantizedTensor {
    pub codebook: Codebook,
    pub alpha: f32,
    pub shape: Vec<usize>,
    /// Level index per element (codebooks are small; u16 suffices).
    pub indices: Vec<u16>,
}

impl QuantizedTensor {
    /// Quantize `data` (row-major, any shape) against `codebook`.
    pub fn encode(
        codebook: &Codebook,
        data: &[f32],
        shape: &[usize],
        calibration: Calibration,
    ) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        let alpha = calib::pick_alpha(codebook, data, calibration);
        let inv = if alpha > 0.0 { 1.0 / alpha } else { 0.0 };
        let indices = data
            .iter()
            .map(|&w| {
                let x = (w * inv).clamp(-1.0, 1.0);
                codebook.nearest(x).0 as u16
            })
            .collect();
        QuantizedTensor {
            codebook: codebook.clone(),
            alpha,
            shape: shape.to_vec(),
            indices,
        }
    }

    /// Dequantize to f32.
    pub fn decode(&self) -> Vec<f32> {
        self.indices
            .iter()
            .map(|&i| self.codebook.levels[i as usize] * self.alpha)
            .collect()
    }

    /// Dequantize into a [`NamedTensor`].
    pub fn decode_named(&self, name: &str) -> NamedTensor {
        NamedTensor::new(name, self.shape.clone(), self.decode())
    }

    pub fn numel(&self) -> usize {
        self.indices.len()
    }

    /// Storage cost in bits per weight for this codebook (`ceil(log2 L)`
    /// — the paper's `b`).
    pub fn bits_per_weight(&self) -> u32 {
        (self.codebook.len() as f64).log2().ceil() as u32
    }
}

/// Convenience: quantize-then-dequantize ("fake quantization") — what the
/// accuracy experiments apply to trained weights.
pub fn fake_quantize(
    codebook: &Codebook,
    data: &[f32],
    calibration: Calibration,
) -> Vec<f32> {
    let shape = [data.len()];
    QuantizedTensor::encode(codebook, data, &shape, calibration).decode()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_codebook() -> Codebook {
        Codebook::new(vec![-1.0, -0.5, 0.0, 0.5, 1.0], "toy")
    }

    #[test]
    fn nearest_picks_closest() {
        let cb = toy_codebook();
        assert_eq!(cb.nearest(0.6).1, 0.5);
        assert_eq!(cb.nearest(0.8).1, 1.0);
        assert_eq!(cb.nearest(-0.6).1, -0.5);
        assert_eq!(cb.nearest(0.0).1, 0.0);
    }

    #[test]
    fn nearest_clamps() {
        let cb = toy_codebook();
        assert_eq!(cb.nearest(5.0).1, 1.0);
        assert_eq!(cb.nearest(-5.0).1, -1.0);
    }

    #[test]
    fn nearest_tie_breaks_low() {
        let cb = toy_codebook();
        // 0.25 is equidistant from 0.0 and 0.5.
        assert_eq!(cb.nearest(0.25).1, 0.0);
    }

    #[test]
    fn encode_decode_roundtrip_on_levels() {
        let cb = toy_codebook();
        let data = [1.0, -0.5, 0.0, 0.5];
        let q = QuantizedTensor::encode(&cb, &data, &[4], Calibration::MaxAbs);
        assert_eq!(q.alpha, 1.0);
        assert_eq!(q.decode(), data.to_vec());
    }

    #[test]
    fn encode_scales_by_alpha() {
        let cb = toy_codebook();
        let data = [4.0, -2.0, 0.0, 2.0];
        let q = QuantizedTensor::encode(&cb, &data, &[4], Calibration::MaxAbs);
        assert_eq!(q.alpha, 4.0);
        assert_eq!(q.decode(), vec![4.0, -2.0, 0.0, 2.0]);
    }

    #[test]
    fn all_zero_tensor_is_fine() {
        let cb = toy_codebook();
        let data = [0.0; 8];
        let q = QuantizedTensor::encode(&cb, &data, &[8], Calibration::MaxAbs);
        assert_eq!(q.decode(), vec![0.0; 8]);
    }

    #[test]
    fn tail_density_toy() {
        let cb = toy_codebook();
        // |l| > 0.75 → {-1, 1} → 2/5.
        assert!((cb.tail_density(0.75) - 0.4).abs() < 1e-9);
    }

    #[test]
    fn max_gap_toy() {
        let cb = toy_codebook();
        assert_eq!(cb.max_gap_in(-1.0, 1.0), 0.5);
    }

    #[test]
    fn validate_rejects_asymmetric() {
        let cb = Codebook { levels: vec![0.0, 0.5], scheme: "bad".into() };
        assert!(cb.validate().is_err());
    }

    #[test]
    fn validate_rejects_missing_zero() {
        let cb = Codebook { levels: vec![-0.5, 0.5], scheme: "bad".into() };
        assert!(cb.validate().is_err());
    }
}
